//! Blocking-scheme enumeration with capacity pruning.
//!
//! Enumeration is the front of the staged pipeline: the recursive
//! descent applies the engine's stage-2 capacity check to every partial
//! level assignment (a partial tile that already overflows its level
//! kills the whole subtree), memoizes per-layer divisor tables through
//! [`DivisorCache`], and can either collect all surviving tables
//! ([`enumerate_blockings`]) or stream them to a visitor as they are
//! found ([`enumerate_blockings_visit`]) — the branch-and-bound optimizer
//! uses the streaming form so the incumbent tightens while enumeration is
//! still running.

use crate::arch::{Arch, LevelKind};
use crate::engine::{DivisorCache, PruneMode};
use crate::loopnest::{Dim, Shape, ALL_DIMS, NDIMS};
use crate::util::divisors;

/// Search options.
#[derive(Debug, Clone)]
pub struct SearchOpts {
    /// Hard cap on enumerated blockings (the paper's "conservatively
    /// pruned search"); enumeration stops once reached.
    pub max_blockings: usize,
    /// Max divisor choices considered per dim per level (geometrically
    /// subsampled when a bound has more divisors).
    pub max_divisors: usize,
    /// Cap on per-level loop-order combinations tried per blocking
    /// (3 stationary candidates per level, cartesian across levels).
    pub max_order_combos: usize,
    /// How candidate evaluation treats the incumbent (see
    /// [`PruneMode`]); branch-and-bound by default.
    pub prune: PruneMode,
}

impl Default for SearchOpts {
    fn default() -> Self {
        SearchOpts {
            max_blockings: 150_000,
            max_divisors: 8,
            max_order_combos: 81,
            prune: PruneMode::BranchAndBound,
        }
    }
}

impl SearchOpts {
    /// Convenience constructor for the common (blockings, divisors) pair.
    pub fn capped(max_blockings: usize, max_divisors: usize) -> Self {
        SearchOpts {
            max_blockings,
            max_divisors,
            ..Default::default()
        }
    }

    /// Same options with a different [`PruneMode`].
    pub fn with_prune(mut self, prune: PruneMode) -> Self {
        self.prune = prune;
        self
    }
}

/// All ordered `levels`-tuples of factors of `n` (divisor chains), e.g.
/// `factor_splits(12, 2)` = [1,12], [2,6], [3,4], ..., [12,1].
pub fn factor_splits(n: u64, levels: usize) -> Vec<Vec<u64>> {
    fn rec(rem: u64, left: usize, cur: &mut Vec<u64>, out: &mut Vec<Vec<u64>>) {
        if left == 1 {
            cur.push(rem);
            out.push(cur.clone());
            cur.pop();
            return;
        }
        for d in divisors(rem) {
            cur.push(d);
            rec(rem / d, left - 1, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(n, levels, &mut Vec::new(), &mut out);
    out
}

/// Geometrically subsample a divisor list down to at most `cap` entries,
/// always keeping 1 and the maximum.
fn subsample(ds: &[u64], cap: usize) -> Vec<u64> {
    if ds.len() <= cap {
        return ds.to_vec();
    }
    let n = ds.len();
    let mut keep = Vec::with_capacity(cap);
    for i in 0..cap {
        let idx = (i as f64 / (cap - 1) as f64 * (n - 1) as f64).round() as usize;
        keep.push(ds[idx]);
    }
    keep.dedup();
    keep
}

/// Enumerate temporal blocking factor tables for `shape` on `arch` with
/// fixed spatial factors, collecting every surviving table. Each returned
/// table is `factors[level][dim]` (innermost level first, DRAM last = the
/// leftover), and every on-chip level's three tiles fit the level
/// capacity with double buffering.
pub fn enumerate_blockings(
    shape: &Shape,
    arch: &Arch,
    spatial: [u64; NDIMS],
    opts: &SearchOpts,
) -> Vec<Vec<[u64; NDIMS]>> {
    let mut cache = DivisorCache::new();
    enumerate_blockings_cached(shape, arch, spatial, opts, &mut cache)
}

/// [`enumerate_blockings`] with a caller-supplied divisor cache, so
/// repeated enumerations (the same layer shape across many architecture
/// points in a `netopt` shard) share the memoized divisor tables.
pub fn enumerate_blockings_cached(
    shape: &Shape,
    arch: &Arch,
    spatial: [u64; NDIMS],
    opts: &SearchOpts,
    cache: &mut DivisorCache,
) -> Vec<Vec<[u64; NDIMS]>> {
    let mut out = Vec::new();
    enumerate_blockings_visit(shape, arch, spatial, opts, cache, |table| {
        out.push(table.to_vec());
        true
    });
    out
}

/// Streaming form of [`enumerate_blockings`]: `visit` is called with each
/// complete, capacity-feasible table (borrowed; copy it to keep it) and
/// returns `false` to stop enumeration early. The divisor cache is
/// caller-supplied so a layer's repeated enumerations share the memoized
/// tables.
pub fn enumerate_blockings_visit<F: FnMut(&[[u64; NDIMS]]) -> bool>(
    shape: &Shape,
    arch: &Arch,
    spatial: [u64; NDIMS],
    opts: &SearchOpts,
    cache: &mut DivisorCache,
    visit: F,
) {
    let nlv = arch.num_levels();
    let sp = arch.rf_levels();

    // per-dim remaining bound after spatial unrolling
    let mut total = [0u64; NDIMS];
    for d in ALL_DIMS {
        debug_assert_eq!(shape.bound(d) % spatial[d.idx()], 0);
        total[d.idx()] = shape.bound(d) / spatial[d.idx()];
    }

    // recursive enumeration: level by level, dim by dim within a level
    struct Ctx<'a, F> {
        shape: &'a Shape,
        arch: &'a Arch,
        spatial: [u64; NDIMS],
        sp: usize,
        nlv: usize,
        opts: &'a SearchOpts,
        cache: &'a mut DivisorCache,
        table: Vec<[u64; NDIMS]>,
        cum: [u64; NDIMS], // cumulative incl. spatial once past sp
        rem: [u64; NDIMS],
        emitted: usize,
        stopped: bool,
        visit: F,
    }

    impl<F: FnMut(&[[u64; NDIMS]]) -> bool> Ctx<'_, F> {
        /// Stage-2 partial capacity check: even a partially assigned
        /// level must fit (unset dims contribute at least their current
        /// cumulative product), so an overflowing prefix prunes its whole
        /// subtree.
        fn tiles_fit(&self, level: usize) -> bool {
            if self.arch.levels[level].kind == LevelKind::Dram {
                return true;
            }
            let c = &self.cum;
            let s = self.shape;
            let w = c[1] * c[2] * c[5] * c[6]; // K C FX FY
            let o = c[0] * c[1] * c[3] * c[4]; // B K X Y
            let ix = ((c[3] - 1) * s.stride as u64 + c[5]).min(s.input_x());
            let iy = ((c[4] - 1) * s.stride as u64 + c[6]).min(s.input_y());
            let i = c[0] * c[2] * ix * iy;
            2 * (w + o + i) <= self.arch.level_words(level)
        }

        fn done(&self) -> bool {
            self.stopped || self.emitted >= self.opts.max_blockings
        }

        fn rec_dim(&mut self, level: usize, di: usize) {
            if self.done() {
                return;
            }
            if di == NDIMS {
                if self.tiles_fit(level) {
                    self.rec_level(level + 1);
                }
                return;
            }
            // last level takes the remainder
            if level == self.nlv - 1 {
                let f = self.rem[di];
                self.table[level][di] = f;
                let keep = self.cum[di];
                self.cum[di] *= f;
                self.rem[di] = 1;
                self.rec_dim(level, di + 1);
                self.rem[di] = f;
                self.cum[di] = keep;
                self.table[level][di] = 1;
                return;
            }
            let all = self.cache.divisors(self.rem[di]);
            let ds = subsample(all.as_slice(), self.opts.max_divisors);
            for f in ds {
                self.table[level][di] = f;
                let keep_cum = self.cum[di];
                let keep_rem = self.rem[di];
                self.cum[di] *= f;
                self.rem[di] /= f;
                // early prune: even a partial level must fit (the unset
                // dims contribute at least their current cum)
                if self.arch.levels[level].kind == LevelKind::Dram || self.tiles_fit(level) {
                    self.rec_dim(level, di + 1);
                }
                self.cum[di] = keep_cum;
                self.rem[di] = keep_rem;
                self.table[level][di] = 1;
                if self.done() {
                    return;
                }
            }
        }

        fn rec_level(&mut self, level: usize) {
            if self.done() {
                return;
            }
            if level == self.nlv {
                self.emitted += 1;
                if !(self.visit)(&self.table) {
                    self.stopped = true;
                }
                return;
            }
            if level == self.sp {
                // crossing the array: spatial factors join the cumulative
                for d in 0..NDIMS {
                    self.cum[d] *= self.spatial[d];
                }
                self.rec_dim(level, 0);
                for d in 0..NDIMS {
                    self.cum[d] /= self.spatial[d];
                }
            } else {
                self.rec_dim(level, 0);
            }
        }
    }

    let mut ctx = Ctx {
        shape,
        arch,
        spatial,
        sp,
        nlv,
        opts,
        cache,
        table: vec![[1; NDIMS]; nlv],
        cum: [1; NDIMS],
        rem: total,
        emitted: 0,
        stopped: false,
        visit,
    };
    ctx.rec_level(0);
}

/// Convenience: bound of dim `d` in a factor table (product over levels).
pub fn table_bound(table: &[[u64; NDIMS]], d: Dim) -> u64 {
    table.iter().map(|row| row[d.idx()]).product()
}

#[cfg(test)]
mod unit {
    use super::*;
    use crate::arch::eyeriss_like;

    #[test]
    fn factor_splits_basic() {
        let s = factor_splits(12, 2);
        assert!(s.contains(&vec![3, 4]));
        assert!(s.contains(&vec![12, 1]));
        assert_eq!(s.len(), 6); // divisors of 12
        for v in &s {
            assert_eq!(v.iter().product::<u64>(), 12);
        }
    }

    #[test]
    fn factor_splits_three_levels() {
        let s = factor_splits(8, 3);
        // ordered 3-splits of 2^3: C(3+2,2) = 10
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn subsample_keeps_ends() {
        let ds = divisors(720720);
        let s = subsample(&ds, 6);
        assert!(s.len() <= 6);
        assert_eq!(s[0], 1);
        assert_eq!(*s.last().unwrap(), 720720);
    }

    #[test]
    fn enumerated_blockings_are_valid_and_fit() {
        let shape = Shape::new(2, 16, 16, 6, 6, 3, 3, 1);
        let arch = eyeriss_like();
        let opts = SearchOpts::capped(5000, 6);
        let tables = enumerate_blockings(&shape, &arch, [1; NDIMS], &opts);
        assert!(!tables.is_empty());
        for t in tables.iter().take(200) {
            for d in ALL_DIMS {
                assert_eq!(table_bound(t, d), shape.bound(d));
            }
            // RF tile fits 512 B / 2 B words / double buffer
            let c = &t[0];
            let w = c[1] * c[2] * c[5] * c[6];
            let o = c[0] * c[1] * c[3] * c[4];
            assert!(2 * (w + o) <= 256, "RF overflow: {t:?}");
        }
    }

    #[test]
    fn cap_respected() {
        let shape = Shape::new(4, 64, 64, 14, 14, 3, 3, 1);
        let arch = eyeriss_like();
        let opts = SearchOpts::capped(100, 8);
        let tables = enumerate_blockings(&shape, &arch, [1; NDIMS], &opts);
        assert!(tables.len() <= 100);
        assert!(!tables.is_empty());
    }

    #[test]
    fn visitor_streams_same_tables_as_collection() {
        let shape = Shape::new(2, 16, 16, 6, 6, 3, 3, 1);
        let arch = eyeriss_like();
        let opts = SearchOpts::capped(800, 5);
        let collected = enumerate_blockings(&shape, &arch, [1; NDIMS], &opts);
        let mut streamed = Vec::new();
        let mut cache = DivisorCache::new();
        enumerate_blockings_visit(&shape, &arch, [1; NDIMS], &opts, &mut cache, |t| {
            streamed.push(t.to_vec());
            true
        });
        assert_eq!(collected, streamed);
        let (hits, misses) = cache.stats();
        assert!(hits > misses, "divisor memoization should mostly hit");
    }

    #[test]
    fn visitor_can_stop_early() {
        let shape = Shape::new(2, 16, 16, 6, 6, 3, 3, 1);
        let arch = eyeriss_like();
        let opts = SearchOpts::capped(5000, 6);
        let mut cache = DivisorCache::new();
        let mut seen = 0usize;
        enumerate_blockings_visit(&shape, &arch, [1; NDIMS], &opts, &mut cache, |_| {
            seen += 1;
            seen < 10
        });
        assert_eq!(seen, 10);
    }
}
