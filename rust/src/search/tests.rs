//! Tests for the search / optimizer layer.

use super::*;
use crate::arch::{eyeriss_like, small_rf, ArrayShape};
use crate::dataflow::Dataflow;
use crate::energy::Table3;
use crate::engine::PruneMode;
use crate::loopnest::{Dim, Shape};
use crate::util::prop;

fn small_conv() -> Shape {
    Shape::new(2, 16, 16, 6, 6, 3, 3, 1)
}

#[test]
fn divisor_replication_is_exact_factorization() {
    let shape = Shape::new(16, 384, 256, 13, 13, 3, 3, 1);
    let arr = ArrayShape { rows: 16, cols: 16 };
    let df = Dataflow::parse("C|K").unwrap();
    let m = divisor_replication(&shape, &df, &arr);
    // C=256 -> 16, K=384 -> 16
    assert_eq!(m.extent(Dim::C), 16);
    assert_eq!(m.extent(Dim::K), 16);
    for (d, e) in m.u.iter().chain(m.v.iter()) {
        assert_eq!(shape.bound(*d) % e, 0, "extent must divide");
    }
    assert!(m.axis_extent(true) <= 16 && m.axis_extent(false) <= 16);
}

#[test]
fn divisor_replication_fills_awkward_dims() {
    // FY|Y: FY=3, Y=13 on 16x16 -> replication should add more loops
    let shape = Shape::new(16, 384, 256, 13, 13, 3, 3, 1);
    let arr = ArrayShape { rows: 16, cols: 16 };
    let df = Dataflow::parse("FY|Y").unwrap();
    let m = divisor_replication(&shape, &df, &arr);
    assert!(m.pes_used() > 3 * 13, "replication should beat {}", 3 * 13);
}

#[test]
fn optimize_layer_finds_fitting_low_energy_mapping() {
    let shape = small_conv();
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let opts = SearchOpts::capped(3000, 6);
    let lo = optimize_layer(&shape, &arch, &df, &Table3, &opts, 2).expect("found");
    lo.mapping.validate().unwrap();
    assert!(lo.result.energy_pj > 0.0);
    assert!(lo.evaluated > 0);
    // the best mapping must beat a trivial DRAM-everything mapping by a lot
    let trivial = crate::loopnest::Mapping::trivial(shape, 1, 2);
    let t_res = crate::xmodel::evaluate(
        &trivial,
        &crate::dataflow::SpatialMap::scalar(),
        &arch,
        &Table3,
    )
    .unwrap();
    assert!(
        lo.result.energy_pj < t_res.energy_pj / 2.0,
        "optimized {} vs trivial {}",
        lo.result.energy_pj,
        t_res.energy_pj
    );
}

#[test]
fn smaller_rf_wins_on_small_conv() {
    // Observation 2 / Fig 12: the 64 B RF config beats the 512 B one.
    let shape = small_conv();
    let df = Dataflow::parse("C|K").unwrap();
    let opts = SearchOpts::capped(2000, 6);
    let big = optimize_layer(&shape, &eyeriss_like(), &df, &Table3, &opts, 2).unwrap();
    let small = optimize_layer(&shape, &small_rf(), &df, &Table3, &opts, 2).unwrap();
    assert!(
        small.result.energy_pj < big.result.energy_pj,
        "64B RF {} should beat 512B RF {}",
        small.result.energy_pj,
        big.result.energy_pj
    );
}

#[test]
fn optimize_network_caches_equal_shapes() {
    let net = crate::nn::network("lstm-m", 1).unwrap(); // 8 identical gate layers
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let opts = SearchOpts::capped(500, 5);
    let opt = optimize_network(&net, &arch, &df, &Table3, &opts, 2);
    assert_eq!(opt.per_layer.len(), 8);
    let e0 = opt.per_layer[0].as_ref().unwrap().result.energy_pj;
    for lo in &opt.per_layer {
        assert_eq!(lo.as_ref().unwrap().result.energy_pj, e0);
    }
    assert!((opt.total_energy_pj - 8.0 * e0).abs() < 1e-6 * opt.total_energy_pj);
    assert!(opt.tops_per_watt() > 0.0);
}

#[test]
fn optimize_network_reports_unmapped_layers() {
    // a 4 B RF cannot hold even one double-buffered element per tensor,
    // so no blocking fits and every layer comes back unmapped
    let arch = crate::arch::Arch {
        name: "rf-too-small".into(),
        levels: vec![
            crate::arch::MemLevel::reg("RF", 4),
            crate::arch::MemLevel::sram("GBUF", 128 << 10),
            crate::arch::MemLevel::dram(),
        ],
        array: ArrayShape { rows: 4, cols: 4 },
        bus: crate::arch::ArrayBus::Systolic,
        word_bytes: 2,
        dram_bw_bytes_per_cycle: 16.0,
    };
    let net = crate::nn::network("mlp-m", 4).unwrap();
    let df = Dataflow::parse("C|K").unwrap();
    let opt = optimize_network(&net, &arch, &df, &Table3, &SearchOpts::capped(200, 4), 2);
    assert_eq!(opt.unmapped, net.layers.len());
    assert_eq!(opt.unmapped_layers, vec![0, 1, 2]);
    assert_eq!(opt.total_energy_pj, 0.0);
    assert!(opt.per_layer.iter().all(|l| l.is_none()));

    // and a normal architecture maps everything
    let ok = optimize_network(
        &net,
        &eyeriss_like(),
        &df,
        &Table3,
        &SearchOpts::capped(200, 4),
        2,
    );
    assert_eq!(ok.unmapped, 0);
    assert!(ok.unmapped_layers.is_empty());
}

#[test]
fn seeded_layer_search_respects_admissible_and_clipping_bounds() {
    let shape = small_conv();
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let opts = SearchOpts::capped(800, 5);
    let plain = optimize_layer(&shape, &arch, &df, &Table3, &opts, 1).unwrap();

    // seeding exactly at the optimum is admissible: identical winner
    let mut cache = crate::engine::DivisorCache::new();
    let (seeded, _) = optimize_layer_seeded(
        &shape,
        &arch,
        &df,
        &Table3,
        &opts,
        1,
        plain.result.energy_pj,
        &mut cache,
    );
    let seeded = seeded.expect("seed at the optimum keeps the winner");
    assert_eq!(seeded.result.energy_pj, plain.result.energy_pj);
    assert_eq!(seeded.mapping, plain.mapping);

    // a sub-floor seed prunes every candidate away (the clipped case
    // netopt's rerun fallback exists for) — and the empty search still
    // reports the engine work it did
    let mut cache = crate::engine::DivisorCache::new();
    let (clipped, snap) = optimize_layer_seeded(
        &shape,
        &arch,
        &df,
        &Table3,
        &opts,
        1,
        plain.result.energy_pj * 1e-6,
        &mut cache,
    );
    assert!(clipped.is_none(), "sub-floor seed must clip the search");
    assert!(snap.pruned > 0, "clipped search must report its pruning");
}

#[test]
fn hierarchy_search_returns_sorted_and_beats_eyeriss_rf() {
    // tiny MLP so the sweep is fast; the winner should use a small RF
    let net = crate::nn::network("mlp-m", 16).unwrap();
    let opts = SearchOpts::capped(300, 5);
    let results = search_hierarchy(
        &net,
        ArrayShape { rows: 8, cols: 8 },
        &Table3,
        &opts,
        2,
    );
    assert!(results.len() > 4);
    for w in results.windows(2) {
        assert!(w[0].opt.total_energy_pj <= w[1].opt.total_energy_pj);
    }
    // best RF should be small (Observation 2)
    let best_rf = results[0].arch.levels[0].size_bytes;
    assert!(best_rf <= 128, "winner RF was {best_rf} B");
}

#[test]
fn sweep_blockings_has_spread() {
    // Fig 10's premise: blocking choice spreads energy widely
    let shape = small_conv();
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let opts = SearchOpts::capped(1500, 5);
    let energies = sweep_blockings(&shape, &arch, &df, &Table3, &opts, 2);
    assert!(energies.len() > 50);
    let lo = energies.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = energies.iter().cloned().fold(0.0, f64::max);
    assert!(hi / lo > 1.5, "expected >1.5x spread, got {}", hi / lo);
}

#[test]
fn branch_and_bound_matches_exhaustive_with_fewer_full_evals() {
    // the engine's pruning contract, end to end: identical winner
    // (bit-for-bit energy AND mapping), strictly fewer stage-4 completions
    let shape = small_conv();
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    for threads in [1usize, 3] {
        let ex_opts = SearchOpts::capped(1500, 5).with_prune(PruneMode::Exhaustive);
        let bb_opts = SearchOpts::capped(1500, 5).with_prune(PruneMode::BranchAndBound);
        let ex = optimize_layer(&shape, &arch, &df, &Table3, &ex_opts, threads).unwrap();
        let bb = optimize_layer(&shape, &arch, &df, &Table3, &bb_opts, threads).unwrap();
        assert_eq!(
            ex.result.energy_pj, bb.result.energy_pj,
            "threads={threads}: b&b lost the optimum"
        );
        assert_eq!(ex.mapping, bb.mapping, "threads={threads}: different winner");
        assert_eq!(ex.evaluated, bb.evaluated, "same candidate space");
        assert!(
            bb.stats.full < ex.stats.full,
            "threads={threads}: b&b should complete fewer full evals ({} vs {})",
            bb.stats.full,
            ex.stats.full
        );
        assert!(bb.stats.pruned > 0, "threads={threads}: nothing pruned");
        // exhaustive mode never prunes
        assert_eq!(ex.stats.pruned, 0);
        assert_eq!(ex.stats.full, ex.stats.stage3);
    }
}

#[test]
fn layer_opt_reports_pipeline_stats() {
    let shape = small_conv();
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let opts = SearchOpts::capped(500, 5);
    let lo = optimize_layer(&shape, &arch, &df, &Table3, &opts, 2).unwrap();
    let s = lo.stats;
    assert!(s.stage2 > 0);
    assert_eq!(s.stage3, s.full + s.pruned);
    assert!(s.full >= 1, "at least the winner completed");
}

#[test]
fn prop_random_mappings_valid() {
    prop::for_cases(0x5ea, 100, |rng| {
        let shape = Shape::new(
            rng.range(1, 4),
            rng.range(1, 32),
            rng.range(1, 32),
            rng.range(1, 14),
            rng.range(1, 14),
            rng.range(1, 5),
            rng.range(1, 5),
            1,
        );
        let arch = eyeriss_like();
        let (m, smap) = random_mapping_for_arch(shape, &arch, rng);
        m.validate().unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(m.spatial, smap.factors());
    });
}

#[test]
fn factor_splits_cover_and_multiply() {
    prop::for_cases(0xfac, 50, |rng| {
        let n = rng.range(1, 200);
        let levels = rng.range(2, 4) as usize;
        let splits = factor_splits(n, levels);
        assert!(!splits.is_empty());
        for s in &splits {
            assert_eq!(s.len(), levels);
            assert_eq!(s.iter().product::<u64>(), n);
        }
        // no duplicates
        let mut sorted = splits.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), splits.len());
    });
}
