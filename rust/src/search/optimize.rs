//! Per-layer and per-network optimizers, and the §6.3 auto-optimizer's
//! memory-hierarchy search — all running on the staged evaluation engine
//! ([`crate::engine`]): footprints are computed once per blocking and
//! shared across order candidates, access counting is abandoned as soon
//! as a partial lower bound exceeds the incumbent (branch-and-bound, the
//! default), and only the winning candidate materializes a full
//! [`ModelResult`].
//!
//! Network-level resource co-optimization lives in [`crate::netopt`];
//! [`optimize_network`] and [`search_hierarchy`] are kept as thin
//! compatibility shims over it (the same pattern `xmodel::evaluate`
//! follows over the engine).

use super::enumerate::{
    enumerate_blockings, enumerate_blockings_cached, enumerate_blockings_visit, SearchOpts,
};
use super::par::parallel_map;
use crate::arch::{Arch, ArrayShape};
use crate::dataflow::{Dataflow, SpatialMap};
use crate::energy::CostModel;
use crate::engine::{
    DivisorCache, Engine, EvalCtx, EvalSnapshot, EvalStats, Incumbent, PruneMode, Staged,
};
use crate::loopnest::{Blocking, LevelOrder, Mapping, Shape, Tensor, NDIMS};
use crate::nn::Network;
use crate::util::divisors;
use crate::xmodel::ModelResult;

/// Best mapping found for one layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerOpt {
    /// The winning mapping.
    pub mapping: Mapping,
    /// Its spatial map.
    pub smap: SpatialMap,
    /// Model evaluation of the winner.
    pub result: ModelResult,
    /// Number of candidate (blocking × order) points considered.
    pub evaluated: usize,
    /// Staged-engine pipeline counters for the search (how many
    /// candidates were pruned vs fully evaluated).
    pub stats: EvalSnapshot,
}

/// Replication like [`crate::dataflow::best_replication`] but with
/// divisor-constrained extents, so the result is a valid exact
/// factorization for the energy model. Greedy: primary loops first at
/// their largest fitting divisor, then fill with more loops while
/// utilization improves.
pub fn divisor_replication(shape: &Shape, df: &Dataflow, array: &ArrayShape) -> SpatialMap {
    let mut smap = SpatialMap::scalar();
    let mut used: Vec<crate::loopnest::Dim> = Vec::new();

    for (axis_dims, size, vertical) in [
        (&df.u, array.rows as u64, true),
        (&df.v, array.cols as u64, false),
    ] {
        let mut room = size;
        // primary loops in order
        for &d in axis_dims {
            let e = divisors(shape.bound(d))
                .into_iter()
                .filter(|&e| e <= room)
                .max()
                .unwrap_or(1);
            if e > 1 {
                if vertical {
                    smap.u.push((d, e));
                } else {
                    smap.v.push((d, e));
                }
                room /= e;
                used.push(d);
            }
        }
        // replication fill: add loops while there is room
        loop {
            if room < 2 {
                break;
            }
            let mut best: Option<(crate::loopnest::Dim, u64)> = None;
            for d in crate::loopnest::ALL_DIMS {
                if used.contains(&d) {
                    continue;
                }
                let e = divisors(shape.bound(d))
                    .into_iter()
                    .filter(|&e| e <= room)
                    .max()
                    .unwrap_or(1);
                if e > 1 && best.map(|(_, be)| e > be).unwrap_or(true) {
                    best = Some((d, e));
                }
            }
            match best {
                Some((d, e)) => {
                    if vertical {
                        smap.u.push((d, e));
                    } else {
                        smap.v.push((d, e));
                    }
                    room /= e;
                    used.push(d);
                }
                None => break,
            }
        }
    }
    smap
}

/// Candidate per-level orders: one stationary order per tensor.
fn order_candidates() -> [LevelOrder; 3] {
    [
        LevelOrder::stationary_for(Tensor::Output),
        LevelOrder::stationary_for(Tensor::Weight),
        LevelOrder::stationary_for(Tensor::Input),
    ]
}

/// Enumerate order combos across levels. When the full cartesian product
/// (3^levels) fits the cap, use it; otherwise fall back to a structured
/// subset — uniform stationarity plus a varied outermost level — which
/// covers the distinctions that move energy most (inner levels multiply
/// into every boundary below them). Shared with the heuristic mapper
/// ([`crate::fastmap`]) so its order candidates match the exact search's.
pub(crate) fn order_combos(levels: usize, cap: usize) -> Vec<Vec<LevelOrder>> {
    let cands = order_candidates();
    let full = 3usize.saturating_pow(levels as u32);
    if full <= cap {
        let mut combos: Vec<Vec<LevelOrder>> = vec![vec![]];
        for _ in 0..levels {
            let mut next = Vec::with_capacity(combos.len() * 3);
            for c in &combos {
                for o in cands {
                    let mut n = c.clone();
                    n.push(o);
                    next.push(n);
                }
            }
            combos = next;
        }
        return combos;
    }
    // structured subset: inner levels uniform `a`, outermost level `b`
    let mut combos = Vec::new();
    for a in cands {
        for b in cands {
            let mut v = vec![a; levels];
            if levels > 0 {
                v[levels - 1] = b;
            }
            combos.push(v);
            if combos.len() >= cap {
                return combos;
            }
        }
    }
    combos
}

/// One layer search: the per-candidate staged evaluation shared by the
/// streaming (branch-and-bound) and parallel paths. `Sync`, so worker
/// threads share the incumbent and the counters.
struct LayerSearch<'a> {
    engine: Engine<'a>,
    ctx: EvalCtx,
    smap: &'a SpatialMap,
    spatial: [u64; NDIMS],
    combos: &'a [Vec<LevelOrder>],
    rf: usize,
    shape: Shape,
    stats: &'a EvalStats,
    incumbent: &'a Incumbent,
    bnb: bool,
}

impl LayerSearch<'_> {
    /// Evaluate one blocking table against every order combo. Stage 2
    /// runs once (footprints shared across orders); stage 3 runs bounded
    /// by the tighter of the global incumbent and the local best. Returns
    /// the best `(energy, combo index)`, or `None` when the table does
    /// not fit (or every order was pruned).
    fn eval_table(&self, table: &[[u64; NDIMS]]) -> Option<(f64, usize)> {
        let mut m = Mapping {
            shape: self.shape,
            blocking: Blocking {
                factors: table.to_vec(),
            },
            orders: self.combos[0].clone(),
            spatial: self.spatial,
            spatial_at: self.rf,
        };
        let fp = self.engine.footprints(&m, self.stats).ok()?;
        let mut best: Option<(f64, usize)> = None;
        for (ci, orders) in self.combos.iter().enumerate() {
            m.orders.clone_from(orders);
            let bound = if self.bnb {
                match best {
                    Some((b, _)) => self.incumbent.get().min(b),
                    None => self.incumbent.get(),
                }
            } else {
                f64::INFINITY
            };
            if let Staged::Energy(e) =
                self.engine
                    .energy_bounded(&m, self.smap, &self.ctx, &fp, bound, self.stats)
            {
                if best.map(|(b, _)| e < b).unwrap_or(true) {
                    best = Some((e, ci));
                    if self.bnb {
                        self.incumbent.observe(e);
                    }
                }
            }
        }
        best
    }
}

/// Optimize one layer on one architecture with a fixed dataflow: search
/// enumerated blockings × order combos, minimizing energy. Returns `None`
/// when nothing fits (e.g. the array's spatial tiles overflow the RF).
///
/// With `opts.prune == PruneMode::BranchAndBound` (the default) the
/// engine's stage-2/stage-3 lower bounds drop candidates against a
/// shared incumbent; the winner is identical to exhaustive evaluation
/// (see the engine's pruning contract) while full evaluations drop by an
/// order of magnitude. Single-threaded branch-and-bound streams
/// candidates straight out of the enumerator so pruning starts before
/// enumeration finishes.
pub fn optimize_layer(
    shape: &Shape,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> Option<LayerOpt> {
    let mut cache = DivisorCache::new();
    let seed = f64::INFINITY;
    optimize_layer_seeded(shape, arch, df, cost, opts, threads, seed, &mut cache).0
}

/// [`optimize_layer`] with a caller-supplied starting incumbent and a
/// shared divisor cache — the entry point `netopt`'s network-level
/// branch-and-bound uses. Returns the winner (if any) **and** the
/// engine's pipeline counters, which are reported even when every
/// candidate was pruned or nothing fit, so network-level roll-ups count
/// the work of empty searches too.
///
/// `seed_bound` pre-seeds the shared [`Incumbent`], so candidates whose
/// lower bound exceeds it are pruned from the start (a completed
/// evaluation above the seed is still accepted as the local best).
/// Consequently the result equals the unseeded optimum **only when that
/// optimum is `<= seed_bound`**; with a tighter seed the search may
/// return a worse mapping or `None`. Callers that need exactness must
/// either pass an admissible bound (one no better than the true optimum
/// whenever the result matters) or detect the clipped case and rerun —
/// see `netopt`'s seeding fallback. With `f64::INFINITY` this is exactly
/// [`optimize_layer`]. Exhaustive mode (`opts.prune`) ignores the seed.
#[allow(clippy::too_many_arguments)]
pub fn optimize_layer_seeded(
    shape: &Shape,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
    seed_bound: f64,
    cache: &mut DivisorCache,
) -> (Option<LayerOpt>, EvalSnapshot) {
    let smap = divisor_replication(shape, df, &arch.array);
    let spatial = smap.factors();
    let combos = order_combos(arch.num_levels(), opts.max_order_combos);
    let engine = Engine::new(arch, cost);
    let stats = EvalStats::default();
    let incumbent = Incumbent::with_bound(seed_bound);
    let bnb = opts.prune == PruneMode::BranchAndBound;
    let search = LayerSearch {
        engine,
        ctx: engine.context(shape, &smap),
        smap: &smap,
        spatial,
        combos: &combos,
        rf: arch.rf_levels(),
        shape: *shape,
        stats: &stats,
        incumbent: &incumbent,
        bnb,
    };

    let mut evaluated = 0usize;
    let mut win: Option<(f64, Vec<[u64; NDIMS]>, usize)> = None;
    if bnb && threads <= 1 {
        // streaming branch-and-bound over the enumerator
        enumerate_blockings_visit(shape, arch, spatial, opts, cache, |table| {
            evaluated += search.combos.len();
            if let Some((e, ci)) = search.eval_table(table) {
                if win.as_ref().map(|(we, _, _)| e < *we).unwrap_or(true) {
                    win = Some((e, table.to_vec(), ci));
                }
            }
            true
        });
    } else {
        let tables = enumerate_blockings_cached(shape, arch, spatial, opts, cache);
        evaluated = tables.len() * combos.len();
        let results = parallel_map(tables, threads, |table| {
            search.eval_table(table).map(|(e, ci)| (e, table.clone(), ci))
        });
        // deterministic reduction in enumeration order (strict improvement)
        for r in results.into_iter().flatten() {
            if win.as_ref().map(|(we, _, _)| r.0 < *we).unwrap_or(true) {
                win = Some(r);
            }
        }
    }

    let snap = stats.snapshot();
    let Some((energy, table, ci)) = win else {
        return (None, snap);
    };
    let mapping = Mapping {
        shape: *shape,
        blocking: Blocking { factors: table },
        orders: combos[ci].clone(),
        spatial,
        spatial_at: arch.rf_levels(),
    };
    // stage 4: materialize the winner's full evaluation
    let result = match engine.evaluate(&mapping, &smap) {
        Ok(r) => r,
        Err(_) => return (None, snap),
    };
    debug_assert_eq!(result.energy_pj, energy);
    let lo = LayerOpt {
        mapping,
        smap: smap.clone(),
        result,
        evaluated,
        stats: snap,
    };
    (Some(lo), snap)
}

/// Energy of every enumerated blocking (best order each) — the Fig 10
/// design-space distribution. Per-blocking order scans share the stage-2
/// footprints and prune against the blocking's own best (which preserves
/// each blocking's exact minimum).
pub fn sweep_blockings(
    shape: &Shape,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> Vec<f64> {
    let smap = divisor_replication(shape, df, &arch.array);
    let spatial = smap.factors();
    let tables = enumerate_blockings(shape, arch, spatial, opts);
    let combos = order_combos(arch.num_levels(), opts.max_order_combos.min(27));
    let rf = arch.rf_levels();
    let engine = Engine::new(arch, cost);
    let ctx = engine.context(shape, &smap);
    let stats = EvalStats::default();
    parallel_map(tables, threads, |table| {
        let mut m = Mapping {
            shape: *shape,
            blocking: Blocking {
                factors: table.clone(),
            },
            orders: combos[0].clone(),
            spatial,
            spatial_at: rf,
        };
        let Ok(fp) = engine.footprints(&m, &stats) else {
            return f64::INFINITY;
        };
        let mut best = f64::INFINITY;
        for orders in &combos {
            m.orders.clone_from(orders);
            if let Staged::Energy(e) = engine.energy_bounded(&m, &smap, &ctx, &fp, best, &stats) {
                best = best.min(e);
            }
        }
        best
    })
    .into_iter()
    .filter(|e| e.is_finite())
    .collect()
}

/// Network-level optimization result.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkOpt {
    /// Best mapping per layer (same order as the network's layers).
    pub per_layer: Vec<Option<LayerOpt>>,
    /// Total energy across all layers, pJ.
    pub total_energy_pj: f64,
    /// Total cycles.
    pub total_cycles: f64,
    /// Total MACs.
    pub total_macs: u64,
    /// Number of layers whose search found **no** feasible mapping. Their
    /// contribution is absent from the totals, so any `unmapped > 0`
    /// result under-reports the network and must not be compared against
    /// fully mapped ones (the netopt ranking sorts them last; drivers
    /// report or reject them).
    pub unmapped: usize,
    /// Indices (into `per_layer`) of the unmapped layers.
    pub unmapped_layers: Vec<usize>,
}

impl NetworkOpt {
    /// TOPS/W over the whole network.
    pub fn tops_per_watt(&self) -> f64 {
        2.0 * self.total_macs as f64 / self.total_energy_pj
    }

    /// Achieved throughput in TOPS at a clock of `freq_ghz`.
    pub fn tops(&self, freq_ghz: f64) -> f64 {
        2.0 * self.total_macs as f64 * freq_ghz / self.total_cycles / 1e3
    }

    /// Aggregated engine counters across the per-layer searches.
    pub fn stats(&self) -> EvalSnapshot {
        let mut out = EvalSnapshot::default();
        for lo in self.per_layer.iter().flatten() {
            out.absorb(&lo.stats);
        }
        out
    }
}

/// Optimize every layer of a network on one architecture (dataflow fixed,
/// default `C|K` per Observation 1). Identical layer shapes share one
/// search (VGG's repeated convs, LSTM gate banks).
///
/// Compatibility shim over [`crate::netopt::evaluate_network`] — the
/// single-architecture case of the network co-optimizer, with no
/// cross-architecture bound.
pub fn optimize_network(
    net: &Network,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> NetworkOpt {
    crate::netopt::evaluate_network(net, arch, df, cost, opts, threads)
}

/// One point of the hierarchy search.
#[derive(Debug, Clone, PartialEq)]
pub struct HierarchyResult {
    /// The architecture evaluated.
    pub arch: Arch,
    /// Its network-level optimization.
    pub opt: NetworkOpt,
}

/// The §6.3 auto-optimizer's resource search: sweep memory hierarchies on
/// a fixed PE array (dataflow fixed to `C|K`), filtered by Observation
/// 2's 4–16× aggregate inter-level size-ratio rule. Returns every
/// evaluated point, fully mapped points first, each group sorted by
/// energy (best first).
///
/// Compatibility shim over [`crate::netopt`]: builds the paper-default
/// [`crate::netopt::DesignSpace`] for `array` and runs
/// [`crate::netopt::co_optimize`] with network-level pruning disabled, so
/// — like the pre-netopt implementation — every architecture point is
/// fully evaluated and returned. Callers that only need the winner should
/// prefer `co_optimize` with its default branch-and-bound mode.
pub fn search_hierarchy(
    net: &Network,
    array: ArrayShape,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> Vec<HierarchyResult> {
    let space = crate::netopt::DesignSpace::paper_default(array);
    let cfg = crate::netopt::NetOptConfig::exhaustive(opts.clone(), threads);
    crate::netopt::co_optimize(net, &space, cost, &cfg).ranked
}
