//! Per-layer and per-network optimizers, and the §6.3 auto-optimizer's
//! memory-hierarchy search — all running on the staged evaluation engine
//! ([`crate::engine`]): footprints are computed once per blocking and
//! shared across order candidates, access counting is abandoned as soon
//! as a partial lower bound exceeds the incumbent (branch-and-bound, the
//! default), and only the winning candidate materializes a full
//! [`ModelResult`].

use std::collections::HashMap;

use super::enumerate::{enumerate_blockings, enumerate_blockings_visit, SearchOpts};
use super::par::parallel_map;
use crate::arch::{Arch, ArrayShape, MemLevel};
use crate::dataflow::{Dataflow, SpatialMap};
use crate::energy::CostModel;
use crate::engine::{
    DivisorCache, Engine, EvalCtx, EvalSnapshot, EvalStats, Incumbent, PruneMode, Staged,
};
use crate::loopnest::{Blocking, LevelOrder, Mapping, Shape, Tensor, NDIMS};
use crate::nn::Network;
use crate::util::divisors;
use crate::xmodel::ModelResult;

/// Best mapping found for one layer.
#[derive(Debug, Clone)]
pub struct LayerOpt {
    /// The winning mapping.
    pub mapping: Mapping,
    /// Its spatial map.
    pub smap: SpatialMap,
    /// Model evaluation of the winner.
    pub result: ModelResult,
    /// Number of candidate (blocking × order) points considered.
    pub evaluated: usize,
    /// Staged-engine pipeline counters for the search (how many
    /// candidates were pruned vs fully evaluated).
    pub stats: EvalSnapshot,
}

/// Replication like [`crate::dataflow::best_replication`] but with
/// divisor-constrained extents, so the result is a valid exact
/// factorization for the energy model. Greedy: primary loops first at
/// their largest fitting divisor, then fill with more loops while
/// utilization improves.
pub fn divisor_replication(shape: &Shape, df: &Dataflow, array: &ArrayShape) -> SpatialMap {
    let mut smap = SpatialMap::scalar();
    let mut used: Vec<crate::loopnest::Dim> = Vec::new();

    for (axis_dims, size, vertical) in [
        (&df.u, array.rows as u64, true),
        (&df.v, array.cols as u64, false),
    ] {
        let mut room = size;
        // primary loops in order
        for &d in axis_dims {
            let e = divisors(shape.bound(d))
                .into_iter()
                .filter(|&e| e <= room)
                .max()
                .unwrap_or(1);
            if e > 1 {
                if vertical {
                    smap.u.push((d, e));
                } else {
                    smap.v.push((d, e));
                }
                room /= e;
                used.push(d);
            }
        }
        // replication fill: add loops while there is room
        loop {
            if room < 2 {
                break;
            }
            let mut best: Option<(crate::loopnest::Dim, u64)> = None;
            for d in crate::loopnest::ALL_DIMS {
                if used.contains(&d) {
                    continue;
                }
                let e = divisors(shape.bound(d))
                    .into_iter()
                    .filter(|&e| e <= room)
                    .max()
                    .unwrap_or(1);
                if e > 1 && best.map(|(_, be)| e > be).unwrap_or(true) {
                    best = Some((d, e));
                }
            }
            match best {
                Some((d, e)) => {
                    if vertical {
                        smap.u.push((d, e));
                    } else {
                        smap.v.push((d, e));
                    }
                    room /= e;
                    used.push(d);
                }
                None => break,
            }
        }
    }
    smap
}

/// Candidate per-level orders: one stationary order per tensor.
fn order_candidates() -> [LevelOrder; 3] {
    [
        LevelOrder::stationary_for(Tensor::Output),
        LevelOrder::stationary_for(Tensor::Weight),
        LevelOrder::stationary_for(Tensor::Input),
    ]
}

/// Enumerate order combos across levels. When the full cartesian product
/// (3^levels) fits the cap, use it; otherwise fall back to a structured
/// subset — uniform stationarity plus a varied outermost level — which
/// covers the distinctions that move energy most (inner levels multiply
/// into every boundary below them).
fn order_combos(levels: usize, cap: usize) -> Vec<Vec<LevelOrder>> {
    let cands = order_candidates();
    let full = 3usize.saturating_pow(levels as u32);
    if full <= cap {
        let mut combos: Vec<Vec<LevelOrder>> = vec![vec![]];
        for _ in 0..levels {
            let mut next = Vec::with_capacity(combos.len() * 3);
            for c in &combos {
                for o in cands {
                    let mut n = c.clone();
                    n.push(o);
                    next.push(n);
                }
            }
            combos = next;
        }
        return combos;
    }
    // structured subset: inner levels uniform `a`, outermost level `b`
    let mut combos = Vec::new();
    for a in cands {
        for b in cands {
            let mut v = vec![a; levels];
            if levels > 0 {
                v[levels - 1] = b;
            }
            combos.push(v);
            if combos.len() >= cap {
                return combos;
            }
        }
    }
    combos
}

/// One layer search: the per-candidate staged evaluation shared by the
/// streaming (branch-and-bound) and parallel paths. `Sync`, so worker
/// threads share the incumbent and the counters.
struct LayerSearch<'a> {
    engine: Engine<'a>,
    ctx: EvalCtx,
    smap: &'a SpatialMap,
    spatial: [u64; NDIMS],
    combos: &'a [Vec<LevelOrder>],
    rf: usize,
    shape: Shape,
    stats: &'a EvalStats,
    incumbent: &'a Incumbent,
    bnb: bool,
}

impl LayerSearch<'_> {
    /// Evaluate one blocking table against every order combo. Stage 2
    /// runs once (footprints shared across orders); stage 3 runs bounded
    /// by the tighter of the global incumbent and the local best. Returns
    /// the best `(energy, combo index)`, or `None` when the table does
    /// not fit (or every order was pruned).
    fn eval_table(&self, table: &[[u64; NDIMS]]) -> Option<(f64, usize)> {
        let mut m = Mapping {
            shape: self.shape,
            blocking: Blocking {
                factors: table.to_vec(),
            },
            orders: self.combos[0].clone(),
            spatial: self.spatial,
            spatial_at: self.rf,
        };
        let fp = self.engine.footprints(&m, self.stats).ok()?;
        let mut best: Option<(f64, usize)> = None;
        for (ci, orders) in self.combos.iter().enumerate() {
            m.orders.clone_from(orders);
            let bound = if self.bnb {
                match best {
                    Some((b, _)) => self.incumbent.get().min(b),
                    None => self.incumbent.get(),
                }
            } else {
                f64::INFINITY
            };
            if let Staged::Energy(e) =
                self.engine
                    .energy_bounded(&m, self.smap, &self.ctx, &fp, bound, self.stats)
            {
                if best.map(|(b, _)| e < b).unwrap_or(true) {
                    best = Some((e, ci));
                    if self.bnb {
                        self.incumbent.observe(e);
                    }
                }
            }
        }
        best
    }
}

/// Optimize one layer on one architecture with a fixed dataflow: search
/// enumerated blockings × order combos, minimizing energy. Returns `None`
/// when nothing fits (e.g. the array's spatial tiles overflow the RF).
///
/// With `opts.prune == PruneMode::BranchAndBound` (the default) the
/// engine's stage-2/stage-3 lower bounds drop candidates against a
/// shared incumbent; the winner is identical to exhaustive evaluation
/// (see the engine's pruning contract) while full evaluations drop by an
/// order of magnitude. Single-threaded branch-and-bound streams
/// candidates straight out of the enumerator so pruning starts before
/// enumeration finishes.
pub fn optimize_layer(
    shape: &Shape,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> Option<LayerOpt> {
    let smap = divisor_replication(shape, df, &arch.array);
    let spatial = smap.factors();
    let combos = order_combos(arch.num_levels(), opts.max_order_combos);
    let engine = Engine::new(arch, cost);
    let stats = EvalStats::default();
    let incumbent = Incumbent::new();
    let bnb = opts.prune == PruneMode::BranchAndBound;
    let search = LayerSearch {
        engine,
        ctx: engine.context(shape, &smap),
        smap: &smap,
        spatial,
        combos: &combos,
        rf: arch.rf_levels(),
        shape: *shape,
        stats: &stats,
        incumbent: &incumbent,
        bnb,
    };

    let mut evaluated = 0usize;
    let mut win: Option<(f64, Vec<[u64; NDIMS]>, usize)> = None;
    if bnb && threads <= 1 {
        // streaming branch-and-bound over the enumerator
        let mut cache = DivisorCache::new();
        enumerate_blockings_visit(shape, arch, spatial, opts, &mut cache, |table| {
            evaluated += search.combos.len();
            if let Some((e, ci)) = search.eval_table(table) {
                if win.as_ref().map(|(we, _, _)| e < *we).unwrap_or(true) {
                    win = Some((e, table.to_vec(), ci));
                }
            }
            true
        });
    } else {
        let tables = enumerate_blockings(shape, arch, spatial, opts);
        evaluated = tables.len() * combos.len();
        let results = parallel_map(tables, threads, |table| {
            search.eval_table(table).map(|(e, ci)| (e, table.clone(), ci))
        });
        // deterministic reduction in enumeration order (strict improvement)
        for r in results.into_iter().flatten() {
            if win.as_ref().map(|(we, _, _)| r.0 < *we).unwrap_or(true) {
                win = Some(r);
            }
        }
    }

    let (energy, table, ci) = win?;
    let mapping = Mapping {
        shape: *shape,
        blocking: Blocking { factors: table },
        orders: combos[ci].clone(),
        spatial,
        spatial_at: arch.rf_levels(),
    };
    // stage 4: materialize the winner's full evaluation
    let result = engine.evaluate(&mapping, &smap).ok()?;
    debug_assert_eq!(result.energy_pj, energy);
    Some(LayerOpt {
        mapping,
        smap: smap.clone(),
        result,
        evaluated,
        stats: stats.snapshot(),
    })
}

/// Energy of every enumerated blocking (best order each) — the Fig 10
/// design-space distribution. Per-blocking order scans share the stage-2
/// footprints and prune against the blocking's own best (which preserves
/// each blocking's exact minimum).
pub fn sweep_blockings(
    shape: &Shape,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> Vec<f64> {
    let smap = divisor_replication(shape, df, &arch.array);
    let spatial = smap.factors();
    let tables = enumerate_blockings(shape, arch, spatial, opts);
    let combos = order_combos(arch.num_levels(), opts.max_order_combos.min(27));
    let rf = arch.rf_levels();
    let engine = Engine::new(arch, cost);
    let ctx = engine.context(shape, &smap);
    let stats = EvalStats::default();
    parallel_map(tables, threads, |table| {
        let mut m = Mapping {
            shape: *shape,
            blocking: Blocking {
                factors: table.clone(),
            },
            orders: combos[0].clone(),
            spatial,
            spatial_at: rf,
        };
        let Ok(fp) = engine.footprints(&m, &stats) else {
            return f64::INFINITY;
        };
        let mut best = f64::INFINITY;
        for orders in &combos {
            m.orders.clone_from(orders);
            if let Staged::Energy(e) = engine.energy_bounded(&m, &smap, &ctx, &fp, best, &stats) {
                best = best.min(e);
            }
        }
        best
    })
    .into_iter()
    .filter(|e| e.is_finite())
    .collect()
}

/// Network-level optimization result.
#[derive(Debug, Clone)]
pub struct NetworkOpt {
    /// Best mapping per layer (same order as the network's layers).
    pub per_layer: Vec<Option<LayerOpt>>,
    /// Total energy across all layers, pJ.
    pub total_energy_pj: f64,
    /// Total cycles.
    pub total_cycles: f64,
    /// Total MACs.
    pub total_macs: u64,
}

impl NetworkOpt {
    /// TOPS/W over the whole network.
    pub fn tops_per_watt(&self) -> f64 {
        2.0 * self.total_macs as f64 / self.total_energy_pj
    }

    /// Aggregated engine counters across the per-layer searches.
    pub fn stats(&self) -> EvalSnapshot {
        let mut out = EvalSnapshot::default();
        for lo in self.per_layer.iter().flatten() {
            out.stage2 += lo.stats.stage2;
            out.fit_rejected += lo.stats.fit_rejected;
            out.stage3 += lo.stats.stage3;
            out.pruned += lo.stats.pruned;
            out.full += lo.stats.full;
        }
        out
    }
}

/// Optimize every layer of a network on one architecture (dataflow fixed,
/// default `C|K` per Observation 1). Identical layer shapes share one
/// search (VGG's repeated convs, LSTM gate banks).
pub fn optimize_network(
    net: &Network,
    arch: &Arch,
    df: &Dataflow,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> NetworkOpt {
    let mut cache: HashMap<([u64; NDIMS], u32), Option<LayerOpt>> = HashMap::new();
    let mut per_layer = Vec::with_capacity(net.layers.len());
    let mut total_e = 0.0;
    let mut total_c = 0.0;
    let mut total_m = 0u64;
    for layer in &net.layers {
        let key = (layer.shape.bounds, layer.shape.stride);
        let entry = cache
            .entry(key)
            .or_insert_with(|| optimize_layer(&layer.shape, arch, df, cost, opts, threads))
            .clone();
        if let Some(ref lo) = entry {
            total_e += lo.result.energy_pj;
            total_c += lo.result.cycles;
            total_m += lo.result.macs;
        }
        per_layer.push(entry);
    }
    NetworkOpt {
        per_layer,
        total_energy_pj: total_e,
        total_cycles: total_c,
        total_macs: total_m,
    }
}

/// One point of the hierarchy search.
#[derive(Debug, Clone)]
pub struct HierarchyResult {
    /// The architecture evaluated.
    pub arch: Arch,
    /// Its network-level optimization.
    pub opt: NetworkOpt,
}

/// The §6.3 auto-optimizer's resource search: sweep memory hierarchies on
/// a fixed PE array (dataflow fixed to `C|K`), pruned by Observation 2's
/// 4–16× inter-level size-ratio rule. Returns all evaluated points sorted
/// by energy (best first).
pub fn search_hierarchy(
    net: &Network,
    array: ArrayShape,
    cost: &dyn CostModel,
    opts: &SearchOpts,
    threads: usize,
) -> Vec<HierarchyResult> {
    let df = Dataflow::parse("C|K").unwrap();
    let rf1_sizes = [16u64, 32, 64, 128, 512];
    let sram_sizes = [64u64 << 10, 128 << 10, 256 << 10];

    let mut candidates: Vec<Arch> = Vec::new();
    for &rf in &rf1_sizes {
        for &sram in &sram_sizes {
            // single-level RF
            candidates.push(Arch {
                name: format!("rf{rf}-sram{}", sram >> 10),
                levels: vec![
                    MemLevel::reg("RF", rf),
                    MemLevel::sram("GBUF", sram),
                    MemLevel::dram(),
                ],
                array,
                bus: crate::arch::ArrayBus::Systolic,
                word_bytes: 2,
                dram_bw_bytes_per_cycle: 16.0,
            });
            // two-level RF with ratio-rule second level (4-16x)
            for ratio in [8u64] {
                let rf2 = rf * ratio;
                if rf2 > 1024 {
                    continue;
                }
                candidates.push(Arch {
                    name: format!("rf{rf}+{rf2}-sram{}", sram >> 10),
                    levels: vec![
                        MemLevel::reg("RF1", rf),
                        MemLevel::reg("RF2", rf2),
                        MemLevel::sram("GBUF", sram),
                        MemLevel::dram(),
                    ],
                    array,
                    bus: crate::arch::ArrayBus::Systolic,
                    word_bytes: 2,
                    dram_bw_bytes_per_cycle: 16.0,
                });
            }
        }
    }

    // Observation-2 ratio pruning: on-chip level sizes should step by
    // roughly 4-16x per level *in aggregate* (RF is per-PE).
    let pes = array.pes();
    candidates.retain(|a| {
        let mut sizes: Vec<u64> = Vec::new();
        for l in &a.levels {
            match l.kind {
                crate::arch::LevelKind::Reg => sizes.push(l.size_bytes * pes),
                crate::arch::LevelKind::Sram => sizes.push(l.size_bytes),
                crate::arch::LevelKind::Dram => {}
            }
        }
        sizes.windows(2).all(|w| {
            let r = w[1] as f64 / w[0] as f64;
            (0.25..=64.0).contains(&r)
        })
    });

    let mut results: Vec<HierarchyResult> = candidates
        .into_iter()
        .map(|arch| {
            let opt = optimize_network(net, &arch, &df, cost, opts, threads);
            HierarchyResult { arch, opt }
        })
        .collect();
    results.sort_by(|a, b| {
        a.opt
            .total_energy_pj
            .partial_cmp(&b.opt.total_energy_pj)
            .unwrap()
    });
    results
}
