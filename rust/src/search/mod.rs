//! Design-space search: blocking enumeration with capacity pruning,
//! order selection, divisor-constrained replication, and the per-layer
//! optimizer. The §6.3 auto-optimizer over whole networks (fix `C|K`,
//! 4–16 size-ratio rule) lives in [`crate::netopt`];
//! [`optimize_network`] and [`search_hierarchy`] remain here as thin
//! shims over it.
//!
//! All candidate evaluation goes through the staged engine
//! ([`crate::engine`]); searches run branch-and-bound by default (see
//! [`crate::engine::PruneMode`]) and report pipeline counters in
//! [`LayerOpt::stats`].

mod enumerate;
mod optimize;
mod par;
mod random;

pub use enumerate::{
    enumerate_blockings, enumerate_blockings_cached, enumerate_blockings_visit, factor_splits,
    table_bound, SearchOpts,
};
pub use optimize::{
    divisor_replication, optimize_layer, optimize_layer_seeded, optimize_network,
    search_hierarchy, sweep_blockings, HierarchyResult, LayerOpt, NetworkOpt,
};
pub(crate) use optimize::order_combos;
pub use par::{default_threads, parallel_map};
pub use random::{random_mapping, random_mapping_for_arch};

#[cfg(test)]
mod tests;
