//! Random mapping generators for property tests and randomized search
//! seeding.

use crate::arch::Arch;
use crate::dataflow::SpatialMap;
use crate::engine::DivisorCache;
use crate::loopnest::{Blocking, Dim, LevelOrder, Mapping, Shape, ALL_DIMS, NDIMS};
use crate::util::XorShift;

/// A uniformly-ish random valid mapping: each dim's bound is split across
/// `levels` temporal levels by repeated random divisor choice; orders are
/// random permutations; `rf_levels` per-PE levels; no spatial unrolling.
pub fn random_mapping(shape: Shape, levels: usize, rf_levels: usize, rng: &mut XorShift) -> Mapping {
    assert!(levels >= 2 && rf_levels >= 1 && rf_levels < levels);
    let mut dc = DivisorCache::new();
    let mut blocking = Blocking::ones(levels);
    for d in ALL_DIMS {
        let mut rem = shape.bound(d);
        for l in 0..levels - 1 {
            let ds = dc.divisors(rem);
            let f = *rng.choose(ds.as_slice());
            blocking.set(l, d, f);
            rem /= f;
        }
        blocking.set(levels - 1, d, rem);
    }
    let orders = (0..levels)
        .map(|_| {
            let mut dims = ALL_DIMS;
            rng.shuffle(&mut dims);
            LevelOrder(dims)
        })
        .collect();
    Mapping {
        shape,
        blocking,
        orders,
        spatial: [1; NDIMS],
        spatial_at: rf_levels,
    }
}

/// Random mapping for an architecture, including random spatial extents
/// (divisor-constrained, fitting the array axes). Returns the mapping and
/// the matching [`SpatialMap`].
pub fn random_mapping_for_arch(
    shape: Shape,
    arch: &Arch,
    rng: &mut XorShift,
) -> (Mapping, SpatialMap) {
    let levels = arch.num_levels();
    let rf = arch.rf_levels();
    let mut dc = DivisorCache::new();

    // pick up to one spatial dim per axis with a random divisor extent
    let mut smap = SpatialMap::scalar();
    let mut taken: Vec<Dim> = Vec::new();
    for vertical in [true, false] {
        let size = if vertical { arch.array.rows } else { arch.array.cols } as u64;
        if size < 2 || rng.below(4) == 0 {
            continue; // sometimes leave an axis empty
        }
        let d = *rng.choose(&ALL_DIMS);
        if taken.contains(&d) || shape.bound(d) == 1 {
            continue;
        }
        let all = dc.divisors(shape.bound(d));
        let ds: Vec<u64> = all.iter().copied().filter(|&e| e <= size).collect();
        let e = *rng.choose(&ds);
        if e > 1 {
            if vertical {
                smap.u.push((d, e));
            } else {
                smap.v.push((d, e));
            }
            taken.push(d);
        }
    }

    // split the remaining bounds across temporal levels
    let spatial = smap.factors();
    let mut blocking = Blocking::ones(levels);
    for d in ALL_DIMS {
        let mut rem = shape.bound(d) / spatial[d.idx()];
        for l in 0..levels - 1 {
            let ds = dc.divisors(rem);
            let f = *rng.choose(ds.as_slice());
            blocking.set(l, d, f);
            rem /= f;
        }
        blocking.set(levels - 1, d, rem);
    }
    let orders = (0..levels)
        .map(|_| {
            let mut dims = ALL_DIMS;
            rng.shuffle(&mut dims);
            LevelOrder(dims)
        })
        .collect();
    (
        Mapping {
            shape,
            blocking,
            orders,
            spatial,
            spatial_at: rf,
        },
        smap,
    )
}
