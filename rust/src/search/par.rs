//! Minimal data-parallel map over std::thread (rayon is not in the
//! offline vendor set). Used by the sweep executors.

/// Apply `f` to every item on up to `nthreads` worker threads, preserving
/// input order in the output.
pub fn parallel_map<T, R, F>(items: Vec<T>, nthreads: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let nthreads = nthreads.max(1).min(n);
    if nthreads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_ptr = SyncSlice(out.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..nthreads {
            let next = &next;
            let f = &f;
            let items = &items;
            let out_ptr = &out_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                // SAFETY: each index i is claimed by exactly one thread via
                // the atomic counter, and `out` outlives the scope.
                unsafe {
                    *out_ptr.0.add(i) = Some(r);
                }
            });
        }
    });

    out.into_iter().map(|o| o.expect("worker filled slot")).collect()
}

/// Wrapper making a raw pointer Sync for the disjoint-index write pattern
/// above.
struct SyncSlice<R>(*mut Option<R>);
unsafe impl<R: Send> Sync for SyncSlice<R> {}

/// A sensible default worker count: available parallelism minus one,
/// at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(items, 8, |x| x * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 2 * i as u64);
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u64> = parallel_map(Vec::<u64>::new(), 4, |x| *x);
        assert!(out.is_empty());
        let out = parallel_map(vec![42u64], 4, |x| x + 1);
        assert_eq!(out, vec![43]);
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(vec![1u64, 2, 3], 1, |x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn heavy_work_all_items() {
        let items: Vec<u64> = (0..200).collect();
        let out = parallel_map(items, 16, |x| (0..*x).sum::<u64>());
        assert_eq!(out[10], 45);
        assert_eq!(out.len(), 200);
    }
}
