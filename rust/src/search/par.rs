//! Minimal data-parallel map over `std::thread::scope` (rayon is not in
//! the offline vendor set). Used by the sweep executors.
//!
//! The implementation is fully safe: the input is split into disjoint
//! contiguous chunks (`slice::chunks`), each scoped worker maps its own
//! chunk into an owned `Vec`, and the results are re-joined in spawn
//! order — no shared output buffer, no raw pointers. A panicking worker
//! propagates its panic to the caller at join time (after the remaining
//! workers finish), so partially computed results are never observed.
//!
//! Trade-off vs the previous unsafe work-stealing version: static chunks
//! can load-imbalance when per-item cost is skewed toward one end of the
//! input. The sweep workloads here are wide (hundreds to thousands of
//! items per chunk) and per-item variance is bounded by the staged
//! engine's pruning, so the imbalance stays small; revisit with an
//! index-tagged atomic-counter design if a profile ever says otherwise.

/// Apply `f` to every item on up to `nthreads` worker threads, preserving
/// input order in the output.
pub fn parallel_map<T, R, F>(items: Vec<T>, nthreads: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let nthreads = nthreads.max(1).min(n);
    if nthreads == 1 {
        return items.iter().map(f).collect();
    }

    let chunk_len = n.div_ceil(nthreads);
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = items
            .chunks(chunk_len)
            .map(|chunk| {
                let f = &f;
                scope.spawn(move || chunk.iter().map(f).collect::<Vec<R>>())
            })
            .collect();
        for h in handles {
            out.extend(h.join().expect("parallel_map worker panicked"));
        }
    });
    out
}

/// A sensible default worker count: available parallelism minus one,
/// at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(items, 8, |x| x * 2);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 2 * i as u64);
        }
    }

    #[test]
    fn preserves_order_with_uneven_chunks() {
        // n not divisible by nthreads: the tail chunk is shorter
        for n in [1usize, 7, 97, 1001] {
            for threads in [2usize, 3, 5, 16] {
                let items: Vec<u64> = (0..n as u64).collect();
                let out = parallel_map(items, threads, |x| x + 1);
                assert_eq!(out.len(), n);
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(*v, i as u64 + 1, "n={n} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u64> = parallel_map(Vec::<u64>::new(), 4, |x| *x);
        assert!(out.is_empty());
        let out = parallel_map(vec![42u64], 4, |x| x + 1);
        assert_eq!(out, vec![43]);
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(vec![1u64, 2, 3], 1, |x| x * x);
        assert_eq!(out, vec![1, 4, 9]);
    }

    #[test]
    fn heavy_work_all_items() {
        let items: Vec<u64> = (0..200).collect();
        let out = parallel_map(items, 16, |x| (0..*x).sum::<u64>());
        assert_eq!(out[10], 45);
        assert_eq!(out.len(), 200);
    }

    #[test]
    fn worker_panic_propagates() {
        // a panicking closure must panic the caller, not hang or return
        // partial results
        let result = std::panic::catch_unwind(|| {
            let items: Vec<u64> = (0..64).collect();
            parallel_map(items, 4, |x| {
                if *x == 13 {
                    panic!("boom at 13");
                }
                *x
            })
        });
        assert!(result.is_err(), "panic must propagate out of parallel_map");
    }
}
