//! Fig 11: per-layer energy breakdown of AlexNet, 512 B RF vs 64 B RF.
//! Paper's claims: with a 512 B RF the RF level dominates CONV-layer
//! energy; a 64 B RF cuts total energy substantially; FC layers stay
//! DRAM-bound either way.

use interstellar::coordinator::experiments::{self, Effort};
use interstellar::search::default_threads;
use interstellar::util::bench::Bencher;

fn main() {
    let threads = default_threads();
    let mut b = Bencher::new(1);
    let mut table = None;
    b.bench("fig11/breakdown alexnet", || {
        table = Some(experiments::fig11_breakdown(Effort::Fast, threads));
    });
    let table = table.unwrap();
    println!("\n=== Fig 11: 512 B vs 64 B RF (AlexNet) ===");
    print!("{}", table.to_text());

    // claims on CONV3 row: RF fraction falls, energy falls
    let csv = table.to_csv();
    let conv3 = csv
        .lines()
        .find(|l| l.starts_with("CONV3"))
        .expect("CONV3 row");
    let cells: Vec<&str> = conv3.split(',').collect();
    let rf_frac_big: f64 = cells[3].trim_end_matches('%').parse().unwrap();
    let rf_frac_small: f64 = cells[5].trim_end_matches('%').parse().unwrap();
    let gain: f64 = cells[6].trim_end_matches('x').parse().unwrap();
    println!(
        "\nCONV3: RF fraction {rf_frac_big}% (512B) -> {rf_frac_small}% (64B), gain {gain}x"
    );
    assert!(
        rf_frac_big > 35.0 && rf_frac_big > 2.0 * rf_frac_small,
        "512B RF should be the dominant component and shrink sharply at 64B, \
         got {rf_frac_big}% -> {rf_frac_small}%"
    );
    assert!(gain > 1.3, "64B RF should cut energy, got {gain}x");
    // FC layers are DRAM-bound: RF size barely moves them (paper §6.1)
    let fc6 = csv.lines().find(|l| l.starts_with("FC6")).expect("FC6 row");
    let fc_gain: f64 = fc6
        .split(',')
        .nth(6)
        .unwrap()
        .trim_end_matches('x')
        .parse()
        .unwrap();
    assert!(
        fc_gain < 1.3,
        "FC layers should be insensitive to RF size, got {fc_gain}x"
    );
    println!("\nfig11 OK (Observation 2: no level should dominate)");
}
