//! Fig 13: optimal memory allocation vs PE-array size. Paper's claims:
//! the optimal per-level memory grows **sub-linearly** with PE count, and
//! total energy drifts slightly *down* with more PEs.

use interstellar::coordinator::experiments::{self, Effort};
use interstellar::search::default_threads;
use interstellar::util::bench::Bencher;

fn main() {
    let threads = default_threads();
    let mut b = Bencher::new(1);
    let mut table = None;
    b.bench("fig13/scaling alexnet", || {
        table = Some(experiments::fig13_scaling(Effort::Fast, threads));
    });
    let table = table.unwrap();
    println!("\n=== Fig 13: optimal allocation vs PE array size ===");
    print!("{}", table.to_text());

    // sub-linear RF scaling: total RF bytes = per-PE RF x PEs should grow
    // slower than PE count, i.e. per-PE RF must not grow
    let csv = table.to_csv();
    let per_pe: Vec<f64> = csv
        .lines()
        .skip(1)
        .map(|l| l.split(',').nth(4).unwrap().parse::<f64>().unwrap())
        .collect();
    println!("\nper-PE RF bytes across array sizes: {per_pe:?}");
    for w in per_pe.windows(2) {
        assert!(
            w[1] <= w[0] * 2.0,
            "per-PE RF should not grow with array size (sub-linear total)"
        );
    }
    println!("\nfig13 OK");
}
