//! Fig 9: PE-array utilization per dataflow, with and without
//! replication, on a 16×16 array. The paper's claims: utilization varies
//! wildly without replication; replication lifts almost every dataflow
//! to high utilization; `C|K` beats `FY|Y` on AlexNet CONV3 because the
//! channel dims are large.

use interstellar::arch::ArrayShape;
use interstellar::coordinator::experiments;
use interstellar::dataflow::{best_replication, single_loop_map, utilization, Dataflow};
use interstellar::util::bench::Bencher;
use interstellar::util::stats;

fn main() {
    let conv3 = experiments::alexnet_conv3(16);
    let g4c3r = experiments::googlenet_4c3r(16);
    let array = ArrayShape { rows: 16, cols: 16 };
    let mut b = Bencher::new(200);

    for (name, shape) in [("AlexNet CONV3", conv3), ("GoogLeNet 4C3R", g4c3r)] {
        println!("\n=== Fig 9: {name} ===");
        let t = experiments::fig9_utilization(shape);
        print!("{}", t.to_text());

        // aggregate claims
        let mut no_repl = Vec::new();
        let mut with_repl = Vec::new();
        for line in t.to_csv().lines().skip(1) {
            let mut it = line.split(',');
            it.next();
            no_repl.push(it.next().unwrap().parse::<f64>().unwrap());
            with_repl.push(it.next().unwrap().parse::<f64>().unwrap());
        }
        println!(
            "mean util: {:.2} (no repl) -> {:.2} (repl); min {:.2} -> {:.2}",
            stats::mean(&no_repl),
            stats::mean(&with_repl),
            stats::min(&no_repl),
            stats::min(&with_repl)
        );
        assert!(stats::mean(&with_repl) > stats::mean(&no_repl));
        assert!(stats::mean(&with_repl) > 0.8, "replication should lift mean util > 0.8");
    }

    // C|K vs FY|Y on CONV3 (paper: ~20% better)
    let ck = best_replication(&conv3, &Dataflow::parse("C|K").unwrap(), &array);
    let fyy = single_loop_map(&conv3, &Dataflow::parse("FY|Y").unwrap(), &array);
    let (u_ck, u_fyy) = (
        utilization(&conv3, &ck, &array),
        utilization(&conv3, &fyy, &array),
    );
    println!("\nC|K util {u_ck:.3} vs plain FY|Y {u_fyy:.3} ({:.0}% better)", 100.0 * (u_ck / u_fyy - 1.0));
    assert!(u_ck > u_fyy);

    b.bench("fig9/best_replication conv3 all dataflows", || {
        for df in interstellar::dataflow::enumerate_dataflows(&conv3) {
            std::hint::black_box(best_replication(&conv3, &df, &array));
        }
    });
    println!("\nfig9 OK");
}
