//! §Perf/CI gate: serving-time remapping. Asserts the online-remapping
//! contracts on the synthetic (artifact-free) executor and measures the
//! cost of a request-path re-optimization:
//!
//! 1. **Serve determinism** — `ServeStats.checksum` is bit-identical
//!    across worker counts {1, 2, 4} with remapping enabled, and the
//!    remap count is identical too (remap decisions are pure functions
//!    of the trace).
//! 2. **Static-mix equivalence** — the warm-started online optimizer
//!    (`co_optimize_arches_seeded` fed the cold run's seeds) returns the
//!    bit-identical winner with at most as many fully evaluated
//!    architecture points.
//! 3. **Drift convergence** — on the synthetic drift trace the remapper
//!    re-optimizes and its final plan equals the offline optimum for the
//!    post-drift mix, bit for bit.
//! 4. **Deadline fast path** — end-to-end remap latency from a
//!    triggering window to the *first published plan*: the heuristic
//!    fast path ([`RemapPolicy::with_deadline`]) must publish strictly
//!    faster than the eager exact search it defers.
//!
//! Emits `BENCH_remap.json` for the perf trajectory (validated — and
//! required — by the `bench_schema` gate).

use interstellar::coordinator::remap::{mix_network, RemapPolicy, Remapper};
use interstellar::coordinator::serve::{
    drift_trace, mixed_trace, serve_with, Request, ServeConfig, ServeStats, SyntheticExecutor,
};
use interstellar::energy::Table3;
use interstellar::netopt::{co_optimize_arches, co_optimize_arches_seeded, NetOptConfig};
use interstellar::util::bench::Bencher;
use interstellar::util::json::Json;

fn serve_synthetic(
    trace: Vec<Request>,
    threads: usize,
    batch: usize,
    remapper: Option<&mut Remapper>,
) -> ServeStats {
    serve_with(
        trace,
        &ServeConfig::new(threads).with_batch(batch),
        || Ok(SyntheticExecutor),
        remapper,
    )
    .expect("synthetic serve")
}

fn remapper() -> Remapper {
    Remapper::new(RemapPolicy::new(24, 0.4), Remapper::default_candidates())
}

fn main() {
    let mut b = Bencher::new(200);
    let mut fields: Vec<(String, Json)> = vec![("bench".into(), Json::str("perf_remap"))];

    // 1. determinism across worker counts, remap enabled
    let trace = mixed_trace(200, 99);
    let mut base: Option<(u64, usize)> = None;
    for threads in [1usize, 2, 4] {
        let mut r = remapper();
        let stats = serve_synthetic(trace.clone(), threads, 25, Some(&mut r));
        assert_eq!(stats.completed, 200);
        match base {
            None => base = Some((stats.checksum.to_bits(), stats.remaps)),
            Some((bits, remaps)) => {
                assert_eq!(
                    stats.checksum.to_bits(),
                    bits,
                    "checksum bits differ at threads={threads}"
                );
                assert_eq!(stats.remaps, remaps, "remap count differs at threads={threads}");
            }
        }
    }
    let (_, mixed_remaps) = base.expect("three runs");
    fields.push(("mixed_trace_remaps".into(), Json::int(mixed_remaps as u64)));

    // 2. static-mix equivalence: warm == cold winner, never more points
    let mut r = remapper();
    serve_synthetic(mixed_trace(48, 9), 2, 48, Some(&mut r));
    let plan = r.plan().expect("static-mix plan");
    let (net, weights, _) = mix_network(&plan.mix);
    let cfg = NetOptConfig::new(r.policy().opts.clone(), 1).with_layer_weights(weights);
    let mut cold = None;
    let m_cold = b.bench("perf_remap/co-opt cold", || {
        cold = Some(co_optimize_arches(&net, r.candidates().expect("fixed list"), &Table3, &cfg));
    });
    let cold = cold.expect("cold run");
    let warm_seeds = cold.seeds.clone();
    let mut warm = None;
    let m_warm = b.bench("perf_remap/co-opt warm-started", || {
        warm = Some(co_optimize_arches_seeded(
            &net,
            r.candidates().expect("fixed list"),
            &Table3,
            &cfg,
            &warm_seeds,
        ));
    });
    let warm = warm.expect("warm run");
    let (cw, ww) = (
        cold.best().expect("cold winner"),
        warm.best().expect("warm winner"),
    );
    assert_eq!(cw.arch, ww.arch, "warm start moved the winner arch");
    assert_eq!(
        cw.opt.total_energy_pj.to_bits(),
        ww.opt.total_energy_pj.to_bits(),
        "warm start moved the winner energy bits"
    );
    for (x, y) in cw.opt.per_layer.iter().zip(ww.opt.per_layer.iter()) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.mapping, y.mapping, "warm start moved a winner mapping");
        assert_eq!(x.result, y.result, "warm start moved a winner result");
    }
    assert!(
        warm.stats.evaluated_full <= cold.stats.evaluated_full,
        "warm start evaluated more points ({} > {})",
        warm.stats.evaluated_full,
        cold.stats.evaluated_full
    );
    // the online plan itself equals the offline run on its mix
    assert_eq!(
        plan.winner.opt.total_energy_pj.to_bits(),
        cw.opt.total_energy_pj.to_bits(),
        "online plan diverges from offline optimizer"
    );

    // 3. drift convergence to the post-drift optimum
    let mut r = remapper();
    let stats = serve_synthetic(
        drift_trace(96, 48, &["conv3x3", "fc"], &["lstm_cell"], 11),
        2,
        12,
        Some(&mut r),
    );
    assert!(r.remaps >= 2, "drift never triggered a remap");
    assert_eq!(stats.remaps, r.remaps);
    let plan = r.plan().expect("post-drift plan");
    assert_eq!(
        plan.mix,
        vec![("lstm_cell".to_string(), 24)],
        "final window is not pure post-drift traffic"
    );
    let (net, weights, _) = mix_network(&plan.mix);
    let cfg = NetOptConfig::new(r.policy().opts.clone(), 1).with_layer_weights(weights);
    let offline = co_optimize_arches(&net, r.candidates().expect("fixed list"), &Table3, &cfg);
    let ow = offline.best().expect("offline post-drift winner");
    assert_eq!(plan.winner.arch, ow.arch, "post-drift plan arch diverges");
    assert_eq!(
        plan.winner.opt.total_energy_pj.to_bits(),
        ow.opt.total_energy_pj.to_bits(),
        "post-drift plan energy diverges from offline optimum"
    );

    // serve-loop throughput measurement (no remap, pure loop cost)
    let m_serve = b.bench("perf_remap/serve 200 synthetic", || {
        serve_synthetic(mixed_trace(200, 5), 2, 25, None);
    });

    // 4. drift-to-first-plan latency: a fresh remapper observes one full
    // triggering window, and we time until the first plan is published —
    // the eager path pays the exact b&b search, the deadline path only
    // the heuristic mapper
    let first_plan = |deadline: bool| {
        let policy = RemapPolicy::new(24, 0.4);
        let mut r = Remapper::new(
            if deadline { policy.with_deadline() } else { policy },
            Remapper::default_candidates(),
        );
        for _ in 0..8 {
            r.observe("conv3x3");
            r.observe("fc");
            r.observe("lstm_cell");
        }
        assert!(r.maybe_remap(), "a full window must publish a first plan");
        let plan = r.plan().expect("first plan");
        assert_eq!(plan.fast, deadline, "wrong path published the first plan");
    };
    let m_exact_first = b.bench("perf_remap/first plan (eager exact)", || first_plan(false));
    let m_fast_first = b.bench("perf_remap/first plan (deadline fast path)", || {
        first_plan(true)
    });
    assert!(
        m_fast_first.mean_ns < m_exact_first.mean_ns,
        "fast path is not faster to the first plan: {} ns >= {} ns",
        m_fast_first.mean_ns,
        m_exact_first.mean_ns
    );

    fields.push(("drift_remaps".into(), Json::int(r.remaps as u64)));
    fields.push(("drift_checks".into(), Json::int(r.checks as u64)));
    fields.push(("seeded_shapes".into(), Json::int(r.seeds().len() as u64)));
    fields.push(("final_arch".into(), Json::str(&plan.winner.arch.name)));
    fields.push((
        "final_energy_pj".into(),
        Json::num(plan.winner.opt.total_energy_pj),
    ));
    fields.push((
        "cold_evaluated_full".into(),
        Json::int(cold.stats.evaluated_full as u64),
    ));
    fields.push((
        "warm_evaluated_full".into(),
        Json::int(warm.stats.evaluated_full as u64),
    ));
    fields.push(("cold_engine_full".into(), Json::int(cold.stats.engine.full)));
    fields.push(("warm_engine_full".into(), Json::int(warm.stats.engine.full)));
    fields.push(("mean_ns_co_opt_cold".into(), Json::num(m_cold.mean_ns)));
    fields.push(("mean_ns_co_opt_warm".into(), Json::num(m_warm.mean_ns)));
    fields.push(("mean_ns_serve_200".into(), Json::num(m_serve.mean_ns)));
    fields.push((
        "mean_ns_first_plan_exact".into(),
        Json::num(m_exact_first.mean_ns),
    ));
    fields.push((
        "mean_ns_first_plan_fast".into(),
        Json::num(m_fast_first.mean_ns),
    ));
    fields.push((
        "first_plan_speedup".into(),
        Json::num(m_exact_first.mean_ns / m_fast_first.mean_ns.max(1.0)),
    ));

    interstellar::bench::emit(fields).expect("emit perf trajectory");
    println!(
        "perf_remap OK (deterministic serving, warm-started remap bit-identical to offline, \
         drift tracked to the post-drift optimum, deadline fast path beats eager to first plan)"
    );
}
