//! §Perf/CI gate: the production serving fleet. Drives [`run_fleet`]
//! against the release binary (real OS-process workers, the same
//! launcher path `fleet --hosts` uses) and asserts the fleet contract:
//!
//! 1. **Merge identity** — a 4-worker fleet over interleaved shards of a
//!    240-request mixed trace, with a live controller remapper, merges
//!    to a digest bit-identical to one process serving the whole trace.
//! 2. **Crash + rejoin** — one of 4 workers is SIGKILLed mid-run (a
//!    slow-executor delay stretches its shard so the kill lands
//!    mid-serve); the controller respawns it once a plan has broadcast,
//!    and the rejoined worker finishes on the current plan epoch with
//!    the merged digest still bit-identical to the baseline.
//! 3. **Scenario catalogue** — every scenario in
//!    [`interstellar::fleet::scenarios`] (steady, bursty, mix-flip,
//!    straggler, crash-rejoin, zero-budget) passes as OS processes —
//!    the same configs the in-process fleet tests smoke as threads.
//!
//! Reports p50/p99/p99.9 latency under load from the bursty (paced)
//! scenario and emits `BENCH_fleet.json` for the perf trajectory
//! (validated by the `bench_schema` gate).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use interstellar::coordinator::trace::TraceSpec;
use interstellar::fleet::scenarios::run_all;
use interstellar::fleet::{baseline, run_fleet, FaultSpec, FleetConfig};
use interstellar::util::json::Json;

fn main() {
    let bin = PathBuf::from(env!("CARGO_BIN_EXE_interstellar"));
    let dir =
        std::env::temp_dir().join(format!("interstellar-perf-fleet-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");

    // 1. merge identity: 4 OS-process workers, live remapper, one
    // 240-request trace. The digest must match single-process `serve`.
    let spec = TraceSpec::mixed(240, 42);
    let (want_digest, _) = baseline(&spec).expect("single-process baseline");
    let mut cfg = FleetConfig::new(4, spec, dir.join("merge"));
    cfg.bin = Some(bin.clone());
    cfg.batch = 12;
    cfg.window = 24;
    cfg.drift = 0.9;
    let t = Instant::now();
    let fleet = run_fleet(&cfg).expect("4-worker OS-process fleet");
    let fleet_wall_ms = t.elapsed().as_secs_f64() * 1e3;
    assert_eq!(fleet.completed, 240, "fleet served the whole trace");
    assert_eq!(fleet.respawns, 0, "healthy fleet must not respawn");
    assert_eq!(
        fleet.digest, want_digest,
        "4-worker fleet digest {:016x} != single-process {want_digest:016x}",
        fleet.digest
    );
    println!(
        "perf_fleet: 4 workers over 240 requests: {fleet_wall_ms:.0} ms, digest \
         {:016x} bit-identical to single-process ({} mix records, {} plans)",
        fleet.digest, fleet.mix_records, fleet.remaps
    );

    // 2. crash + rejoin with a real SIGKILL. Worker 1's executor is
    // slowed to 2 ms/request (60-request shard on 2 threads ⇒ ≥ 60 ms
    // of serving), so the 40 ms kill is guaranteed to land mid-run; the
    // respawn is deferred until a plan has broadcast, so the rejoined
    // worker deterministically adopts the current epoch.
    let spec = TraceSpec::mixed(240, 23);
    let (kill_digest, _) = baseline(&spec).expect("kill baseline");
    let mut cfg = FleetConfig::new(4, spec, dir.join("kill"));
    cfg.bin = Some(bin.clone());
    cfg.batch = 12;
    cfg.window = 24;
    cfg.drift = 0.9;
    cfg.slow_worker = Some((1, 2_000_000));
    cfg.fault = Some(FaultSpec {
        worker: 1,
        after: Duration::from_millis(40),
        after_batches: None,
        await_plan: true,
    });
    let killed = run_fleet(&cfg).expect("fault-injected fleet");
    assert!(
        killed.respawns >= 1,
        "SIGKILL injected no crash (victim finished too fast?)"
    );
    assert!(
        killed.plan_epoch.is_some(),
        "no plan broadcast before the rejoin gate opened"
    );
    assert_eq!(
        killed.worker_epochs[1], killed.plan_epoch,
        "rejoined worker is not on the fleet's current plan epoch"
    );
    assert_eq!(
        killed.digest, kill_digest,
        "crash + rejoin perturbed the merged digest"
    );
    println!(
        "perf_fleet: survived SIGKILL of 1/4 workers ({} respawn(s), rejoined on \
         epoch {:?}, digest intact)",
        killed.respawns, killed.plan_epoch
    );

    // 3. the whole scenario catalogue as OS processes. Each scenario
    // re-verifies digest identity against its own baseline plus its
    // invariant (mix-flip replans, straggler tail, zero-budget
    // degradation, ...); latency percentiles under load come from the
    // bursty (paced) scenario.
    let t = Instant::now();
    let outcomes =
        run_all(2, &dir.join("scenarios"), Some(bin)).expect("scenario catalogue");
    let scenarios_wall_ms = t.elapsed().as_secs_f64() * 1e3;
    let bursty = outcomes
        .iter()
        .find(|o| o.name == "bursty")
        .expect("bursty outcome");
    println!(
        "perf_fleet: {} scenarios OK as OS processes in {scenarios_wall_ms:.0} ms \
         (bursty p50 {:.3} ms, p99 {:.3} ms, p99.9 {:.3} ms)",
        outcomes.len(),
        bursty.stats.p50_ms,
        bursty.stats.p99_ms,
        bursty.stats.p999_ms
    );

    let fields: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("perf_fleet")),
        ("requests".into(), Json::int(240)),
        ("workers".into(), Json::int(4)),
        ("fleet_wall_ms".into(), Json::num(fleet_wall_ms)),
        ("digest".into(), Json::str(format!("{:016x}", fleet.digest))),
        ("digest_match".into(), Json::Bool(fleet.digest == want_digest)),
        ("mix_records".into(), Json::int(fleet.mix_records as u64)),
        ("remaps".into(), Json::int(fleet.remaps as u64)),
        ("p50_ms".into(), Json::num(bursty.stats.p50_ms)),
        ("p99_ms".into(), Json::num(bursty.stats.p99_ms)),
        ("p99_9_ms".into(), Json::num(bursty.stats.p999_ms)),
        ("mean_ms".into(), Json::num(bursty.stats.mean_ms)),
        ("kill_respawns".into(), Json::int(killed.respawns as u64)),
        (
            "kill_plan_epoch".into(),
            Json::int(killed.plan_epoch.unwrap_or(0) as u64),
        ),
        ("scenarios".into(), Json::int(outcomes.len() as u64)),
        ("scenarios_wall_ms".into(), Json::num(scenarios_wall_ms)),
    ];
    interstellar::bench::emit(fields).expect("emit perf trajectory");
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "perf_fleet OK (digest bit-identical at 4 workers, SIGKILL rejoin on the \
         broadcast epoch, {} scenarios green)",
        outcomes.len()
    );
}
