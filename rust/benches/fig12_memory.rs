//! Fig 12: memory-hierarchy exploration — total AlexNet energy over the
//! (RF size × SRAM size) grid with C|K. Paper's claims: 32–64 B RFs beat
//! 512 B by up to ~2.6x; SRAM beyond 256 KB plateaus.

use interstellar::coordinator::experiments::{self, Effort};
use interstellar::search::default_threads;
use interstellar::util::bench::Bencher;

fn main() {
    let threads = default_threads();
    let mut b = Bencher::new(1);
    let mut table = None;
    b.bench("fig12/memory_grid alexnet", || {
        table = Some(experiments::fig12_memory(Effort::Fast, threads));
    });
    let table = table.unwrap();
    println!("\n=== Fig 12: RF x SRAM exploration (AlexNet total energy, uJ) ===");
    print!("{}", table.to_text());

    // parse the grid back for the claims
    let csv = table.to_csv();
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for line in csv.lines().skip(1) {
        rows.push(
            line.split(',')
                .skip(1)
                .map(|v| v.parse::<f64>().unwrap())
                .collect(),
        );
    }
    // columns: RF 32,64,128,256,512 ; rows: SRAM 64K..512K
    let best_small_rf = rows
        .iter()
        .map(|r| r[0].min(r[1]))
        .fold(f64::INFINITY, f64::min);
    let best_big_rf = rows.iter().map(|r| r[4]).fold(f64::INFINITY, f64::min);
    let ratio = best_big_rf / best_small_rf;
    println!("\nbest 512B-RF energy / best 32-64B-RF energy = {ratio:.2}x");
    assert!(ratio > 1.3, "small RFs should win clearly, got {ratio:.2}x");
    println!("\nfig12 OK");
}
