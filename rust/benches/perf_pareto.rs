//! §Perf: Pareto-frontier exactness + dominance-pruning effectiveness.
//! On small spaces × {alexnet head, lstm-m, mlp-m} this gate asserts the
//! three frontier contracts:
//!
//! 1. **Exactness** — `pareto_optimize`'s frontier equals, bit for bit
//!    per point, exhaustively evaluating the space (`co_optimize`
//!    exhaustive) and filtering dominated points;
//! 2. **Work reduction** — the vector bound fully evaluates no more
//!    architecture points per workload, and strictly fewer in aggregate
//!    (the FC-family workloads are DRAM-bound in *both* coordinates, so
//!    their oversized-RF points must be abandoned mid-evaluation);
//! 3. **Budget selection** — the min-energy frontier point under a
//!    `min_tops` throughput floor (`PlanSelector::select_min_tops`) is
//!    the scalar `co_optimize` winner under the same floor.
//!
//! Emits `BENCH_pareto.json` for the perf trajectory.

use interstellar::arch::ArrayShape;
use interstellar::energy::Table3;
use interstellar::netopt::{co_optimize, DesignSpace, NetOptConfig};
use interstellar::nn::{network, Network};
use interstellar::pareto::{pareto_optimize, ParetoConfig, PlanSelector};
use interstellar::search::{HierarchyResult, SearchOpts};
use interstellar::util::bench::Bencher;
use interstellar::util::json::Json;

fn small_space() -> DesignSpace {
    let mut s = DesignSpace::paper_default(ArrayShape { rows: 8, cols: 8 });
    s.rf1_sizes = vec![16, 64, 512];
    s.rf2_ratios = vec![8];
    s.gbuf_sizes = vec![64 << 10, 256 << 10];
    s.ratio_min = 0.25;
    s.ratio_max = 64.0;
    s
}

fn small_opts() -> SearchOpts {
    let mut o = SearchOpts::capped(150, 4);
    o.max_order_combos = 9;
    o
}

/// Reference: O(n²) dominance filter over the feasible exhaustive
/// ranking (ascending `(energy, index)`, so earlier == lower grid index
/// on energy ties).
fn exhaustive_frontier(ranked: &[HierarchyResult]) -> Vec<&HierarchyResult> {
    let feas: Vec<&HierarchyResult> = ranked.iter().filter(|r| r.opt.unmapped == 0).collect();
    let mut out = Vec::new();
    for (i, p) in feas.iter().enumerate() {
        let (pe, pc) = (p.opt.total_energy_pj, p.opt.total_cycles);
        let dominated = feas.iter().enumerate().any(|(j, q)| {
            let (qe, qc) = (q.opt.total_energy_pj, q.opt.total_cycles);
            (qe < pe && qc <= pc) || (qe == pe && (qc < pc || (qc == pc && j < i)))
        });
        if !dominated {
            out.push(*p);
        }
    }
    out
}

fn assert_point_eq(tag: &str, a: &HierarchyResult, b: &HierarchyResult) {
    assert_eq!(a.arch.name, b.arch.name, "{tag}: arch differs");
    assert_eq!(
        a.opt.total_energy_pj.to_bits(),
        b.opt.total_energy_pj.to_bits(),
        "{tag}: energy bits differ"
    );
    assert_eq!(
        a.opt.total_cycles.to_bits(),
        b.opt.total_cycles.to_bits(),
        "{tag}: cycle bits differ"
    );
    for (x, y) in a.opt.per_layer.iter().zip(b.opt.per_layer.iter()) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.mapping, y.mapping, "{tag}: mapping differs");
        assert_eq!(x.result.energy_pj, y.result.energy_pj, "{tag}");
    }
}

fn main() {
    // threads = 1 keeps the candidate order (and so the pruning trace)
    // deterministic for the emitted counters.
    let workloads: Vec<Network> = vec![
        network("alexnet", 1).unwrap().head(3),
        network("lstm-m", 1).unwrap(),
        network("mlp-m", 16).unwrap(),
    ];
    let space = small_space();
    let mut b = Bencher::new(1);

    let mut full_ex_total = 0usize;
    let mut full_par_total = 0usize;
    let mut cand_total = 0usize;
    let mut pruned_total = 0usize;
    let mut frontier_sizes: Vec<(String, usize)> = Vec::new();
    let mut mlp_times = (0f64, 0f64);
    let mut mlp_frontier: Option<PlanSelector> = None;

    for net in &workloads {
        let mut ex = None;
        let m_ex = b.bench(&format!("perf_pareto/{} exhaustive", net.name), || {
            ex = Some(co_optimize(
                net,
                &space,
                &Table3,
                &NetOptConfig::exhaustive(small_opts(), 1),
            ));
        });
        let mut par = None;
        let m_par = b.bench(&format!("perf_pareto/{} frontier", net.name), || {
            par = Some(pareto_optimize(
                net,
                &space,
                &Table3,
                &NetOptConfig::new(small_opts(), 1),
                &ParetoConfig::default(),
            ));
        });
        let ex = ex.expect("exhaustive ran");
        let par = par.expect("pareto ran");

        // exactness: frontier == exhaustive + dominance filter, bit for bit
        let reference = exhaustive_frontier(&ex.ranked);
        assert!(!reference.is_empty(), "{}: no feasible point", net.name);
        assert_eq!(
            par.frontier.len(),
            reference.len(),
            "{}: frontier size differs",
            net.name
        );
        for (e, r) in par.frontier.iter().zip(reference.iter()) {
            assert_point_eq(&net.name, &e.result, r);
        }

        // accounting + per-workload work bound
        assert_eq!(ex.stats.evaluated_full, ex.stats.candidates);
        assert_eq!(
            par.stats.pruned + par.stats.evaluated_full,
            par.stats.candidates
        );
        assert!(
            par.stats.evaluated_full <= ex.stats.evaluated_full,
            "{}: vector bound added work ({} > {})",
            net.name,
            par.stats.evaluated_full,
            ex.stats.evaluated_full
        );
        full_ex_total += ex.stats.evaluated_full;
        full_par_total += par.stats.evaluated_full;
        cand_total += par.stats.candidates;
        pruned_total += par.stats.pruned;
        frontier_sizes.push((net.name.clone(), par.frontier.len()));

        if net.name == "mlp-m" {
            mlp_times = (m_ex.mean_ns, m_par.mean_ns);
            mlp_frontier = Some(PlanSelector::new(par.frontier.clone()));
        }

        // budget selection: for each frontier point's throughput, the
        // scalar min_tops winner is exactly the selector's pick
        let sel = PlanSelector::new(par.frontier.clone());
        for entry in sel.entries().iter().take(2) {
            let tops = entry.result.opt.tops(1.0);
            let scalar = co_optimize(
                net,
                &space,
                &Table3,
                &NetOptConfig::new(small_opts(), 1).with_min_tops(tops),
            );
            let w = scalar.best().expect("constrained scalar winner");
            let picked = sel.select_min_tops(tops, 1.0).expect("selector hit");
            assert_point_eq(&format!("{} min-tops", net.name), &picked.result, w);
        }
    }

    // acceptance: strictly fewer full evaluations across the suite
    assert!(
        full_par_total < full_ex_total,
        "dominance pruning must abandon at least one point across the \
         suite ({full_par_total} vs {full_ex_total} full evaluations)"
    );
    assert!(pruned_total > 0, "no point was vector-pruned");

    println!("\n=== perf_pareto: frontier exactness + dominance pruning ===");
    println!(
        "candidates {cand_total}  full(exhaustive) {full_ex_total}  \
         full(pareto) {full_par_total}  pruned {pruned_total}"
    );
    for (name, len) in &frontier_sizes {
        println!("  {name}: {len} frontier points");
    }

    let mlp = mlp_frontier.expect("mlp-m ran");
    // frontier_sizes is in workloads order (alexnet head, lstm-m,
    // mlp-m) — index, don't string-match: `head(3)` decorates the
    // network name ("alexnet[..3]"), so a name lookup would silently
    // record 0 forever.
    assert_eq!(frontier_sizes.len(), 3, "one frontier size per workload");
    let fields: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("perf_pareto")),
        ("candidates_total".into(), Json::int(cand_total as u64)),
        (
            "full_exhaustive_total".into(),
            Json::int(full_ex_total as u64),
        ),
        ("full_pareto_total".into(), Json::int(full_par_total as u64)),
        ("pruned_total".into(), Json::int(pruned_total as u64)),
        (
            "frontier_alexnet_head".into(),
            Json::int(frontier_sizes[0].1 as u64),
        ),
        (
            "frontier_lstm_m".into(),
            Json::int(frontier_sizes[1].1 as u64),
        ),
        (
            "frontier_mlp_m".into(),
            Json::int(frontier_sizes[2].1 as u64),
        ),
        (
            "mlp_min_energy_arch".into(),
            Json::str(&mlp.entries()[0].result.arch.name),
        ),
        ("mean_ns_exhaustive_mlp_m".into(), Json::num(mlp_times.0)),
        ("mean_ns_pareto_mlp_m".into(), Json::num(mlp_times.1)),
    ];
    interstellar::bench::emit(fields).expect("emit perf trajectory");
    println!(
        "perf_pareto OK (exact frontier, strictly fewer full evaluations, \
         budget selection matches the scalar winner)"
    );
}
