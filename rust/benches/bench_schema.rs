//! CI gate: validate every emitted `BENCH_*.json` against the documented
//! perf-trajectory schema (see ARCHITECTURE.md, "CI tiers and the perf
//! trajectory", and `util::bench::validate_bench_json`, whose unit tests
//! pin the rules): a single flat JSON object with a required non-empty
//! `"bench"` string; every other field a scalar (string, bool, finite
//! number).
//!
//! Keeping the files machine-readable is the point — trend tooling can
//! ingest any conforming file without per-bench parsers. Run after the
//! perf benches (`ci.sh` orders this); zero files found is a failure so
//! the gate can never pass vacuously.
//!
//! The same gate also validates the perf-trajectory history
//! (`bench_history.jsonl`, see BENCHMARKS.md): every parseable line must
//! be a schema-conforming history record (torn tails from interrupted
//! appends are tolerated, silently-corrupt records are not), and every
//! required bench must have appended at least one record.

use interstellar::bench::parse_history_line;
use interstellar::util::bench::validate_bench_json;

/// Files the full `ci.sh` perf tier is guaranteed to have produced by
/// the time this gate runs (it is ordered after the perf benches) —
/// their absence means a perf gate silently stopped emitting.
const REQUIRED: &[&str] = &[
    "BENCH_fastmap.json",
    "BENCH_fleet.json",
    "BENCH_hotpath.json",
    "BENCH_netopt.json",
    "BENCH_orchestrator.json",
    "BENCH_pareto.json",
    "BENCH_remap.json",
    "BENCH_search.json",
    "BENCH_shard.json",
    "BENCH_telemetry.json",
];

fn main() {
    let mut checked = 0usize;
    let mut failures = Vec::new();
    let mut seen: Vec<String> = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(".")
        .expect("read cwd")
        .map(|e| e.expect("dir entry"))
        .collect();
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !(name.starts_with("BENCH_") && name.ends_with(".json")) {
            continue;
        }
        let text = std::fs::read_to_string(entry.path())
            .unwrap_or_else(|e| panic!("reading {name}: {e}"));
        match validate_bench_json(&text) {
            Ok(()) => {
                println!("bench_schema: {name} conforms");
                checked += 1;
                seen.push(name);
            }
            Err(e) => failures.push(format!("{name}: {e}")),
        }
    }
    assert!(
        failures.is_empty(),
        "bench schema violations:\n{}",
        failures.join("\n")
    );
    assert!(
        checked > 0,
        "no BENCH_*.json found — run the perf benches first (full ./ci.sh does)"
    );
    let missing: Vec<&str> = REQUIRED
        .iter()
        .filter(|r| !seen.iter().any(|s| s == *r))
        .copied()
        .collect();
    assert!(
        missing.is_empty(),
        "required perf-trajectory files missing: {missing:?} — run the perf benches first \
         (full ./ci.sh does)"
    );

    // Second half of the gate: the perf-trajectory history. Skipped only
    // when history is disabled (INTERSTELLAR_BENCH_HISTORY=off) — with
    // history on, the benches above must have appended, so an empty or
    // missing file is a failure, not a skip.
    match interstellar::bench::history_path() {
        None => println!("bench_schema: history disabled, skipping bench_history check"),
        Some(path) => {
            let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
                panic!(
                    "history enabled but {} is unreadable ({e}) — the perf benches \
                     above should have appended records",
                    path.display()
                )
            });
            let mut valid = 0usize;
            let mut torn = 0usize;
            let mut benches: Vec<String> = Vec::new();
            let mut violations = Vec::new();
            for (i, line) in text.lines().enumerate() {
                match parse_history_line(line) {
                    Ok(Some(rec)) => {
                        valid += 1;
                        if !benches.contains(&rec.bench) {
                            benches.push(rec.bench);
                        }
                    }
                    Ok(None) => torn += 1,
                    Err(e) => violations.push(format!("line {}: {e}", i + 1)),
                }
            }
            assert!(
                violations.is_empty(),
                "history schema violations in {}:\n{}",
                path.display(),
                violations.join("\n")
            );
            assert!(
                valid > 0,
                "{} holds no valid history records — the perf benches above \
                 should have appended",
                path.display()
            );
            let missing: Vec<String> = REQUIRED
                .iter()
                .map(|f| {
                    format!(
                        "perf_{}",
                        f.trim_start_matches("BENCH_").trim_end_matches(".json")
                    )
                })
                .filter(|b| !benches.contains(b))
                .collect();
            assert!(
                missing.is_empty(),
                "benches with no record in {}: {missing:?}",
                path.display()
            );
            println!(
                "bench_schema: {} OK ({valid} records, {torn} torn line(s) tolerated, \
                 {} benches)",
                path.display(),
                benches.len()
            );
        }
    }
    println!("bench_schema OK ({checked} files validated, all required files present)");
}
