//! Fig 7 / Table 4: analytical model vs the exact trace simulator on the
//! three validation ASIC designs (OS4, OS8, WS16) over AlexNet conv
//! layers, plus the Fig 7b Eyeriss-style breakdown.
//!
//! The paper validates its model against post-synthesis designs at < 2 %
//! error; our ground truth is the exact access-counting simulator and the
//! bench FAILS (exit 1) if any error exceeds 2 %.

use interstellar::coordinator::experiments;
use interstellar::search::default_threads;
use interstellar::util::bench::Bencher;

fn main() {
    let threads = default_threads();
    let mut b = Bencher::new(1);

    let mut table = None;
    b.bench("fig7/model_vs_sim_full_sweep", || {
        table = Some(experiments::fig7_validation(threads));
    });
    let table = table.unwrap();
    println!("\n=== Fig 7a / Table 4: model vs simulator ===");
    print!("{}", table.to_text());

    // enforce the paper's validation bound
    let mut worst = 0.0f64;
    for line in table.to_csv().lines().skip(1) {
        let err: f64 = line.split(',').nth(4).unwrap().parse().unwrap();
        worst = worst.max(err);
    }
    println!("\nworst-case error: {worst:.4}% (paper bound: 2%)");
    assert!(worst < 2.0, "validation exceeded the 2% bound");

    println!("\n=== Fig 7b: AlexNet breakdown under Eyeriss RS (FY|Y) ===");
    print!(
        "{}",
        experiments::fig7b_eyeriss_breakdown(experiments::Effort::Fast, threads).to_text()
    );
    println!("\nfig7 OK");
}
