//! §Perf/CI gate: the unified telemetry layer. Asserts the two promises
//! ARCHITECTURE.md makes for tracing ("observes, never steers" and
//! "cheap enough to leave on"):
//!
//! 1. **Bit identity, tracing off** — repeated untraced runs of the
//!    network co-optimizer produce bit-identical winners and identical
//!    staged search statistics (the determinism floor the other gates
//!    pin).
//! 2. **Bit identity, tracing on** — the same workload with a live
//!    recorder produces the *same bits*: winner arch, energy/cycle
//!    bits, full-eval/prune counts. Telemetry must not steer.
//! 3. **Overhead bound** — min-of-N wall clock with tracing on is
//!    within 5% of tracing off on the `perf_search`-family workload
//!    (the staged per-layer engine inside the network B&B).
//! 4. **Trace integrity** — the trace written by the traced co-opt runs
//!    plus one traced in-process fleet scenario (mix-flip: drift,
//!    replans, epoch adoption, per-batch latency histograms) parses
//!    with zero violations (every span begun/ended, parents known) and
//!    covers the engine, search, and fleet planes; the end-of-run
//!    engine gauges must agree with the untraced run's staged counters.
//!    The orchestrator plane is covered by the traced `orchestrate`
//!    run in `ci.sh`.
//!
//! Emits `BENCH_telemetry.json` (overhead ratio, per-plane record
//! counts, `span_engine_stage3_pct`, `fleet_batch_p99_ms_hist`) for the
//! perf trajectory (validated by the `bench_schema` gate).

use std::time::Instant;

use interstellar::arch::ArrayShape;
use interstellar::energy::Table3;
use interstellar::fleet::scenarios::{run_scenario, Scenario};
use interstellar::netopt::{co_optimize, CoOptResult, DesignSpace, NetOptConfig};
use interstellar::nn::{network, Network};
use interstellar::search::SearchOpts;
use interstellar::telemetry;
use interstellar::telemetry::report::{check_trace, merged_latency_hist};
use interstellar::util::json::Json;

const TIMED_RUNS: usize = 3;
const MAX_OVERHEAD: f64 = 1.05;

fn workload() -> (Network, DesignSpace, NetOptConfig) {
    // mlp-m on the paper-default grid: the same staged-engine-inside-
    // network-B&B workload perf_search/perf_netopt gate, big enough to
    // amortize per-record cost, small enough for min-of-N timing.
    let net = network("mlp-m", 32).unwrap();
    let space = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
    let mut opts = SearchOpts::capped(400, 5);
    opts.max_order_combos = 9;
    // threads = 1: deterministic candidate order, so the full-eval and
    // prune counts below are exact fixtures, not races.
    (net, space, NetOptConfig::new(opts, 1))
}

/// Everything the workload computes that telemetry could possibly
/// perturb, collapsed to comparable bits.
#[derive(Debug, PartialEq)]
struct Signature {
    winner: String,
    energy_bits: u64,
    cycle_bits: u64,
    evaluated_full: usize,
    pruned: usize,
    engine_full: u64,
    engine_stage2: u64,
    engine_stage3: u64,
}

fn signature(r: &CoOptResult) -> Signature {
    let w = r.best().expect("co-opt winner");
    Signature {
        winner: w.arch.name.clone(),
        energy_bits: w.opt.total_energy_pj.to_bits(),
        cycle_bits: w.opt.total_cycles.to_bits(),
        evaluated_full: r.stats.evaluated_full,
        pruned: r.stats.pruned,
        engine_full: r.stats.engine.full,
        engine_stage2: r.stats.engine.stage2,
        engine_stage3: r.stats.engine.stage3,
    }
}

fn main() {
    let (net, space, cfg) = workload();
    let scratch = format!("interstellar-perf-telemetry-{}", std::process::id());
    let dir = std::env::temp_dir().join(scratch);
    std::fs::create_dir_all(&dir).expect("create scratch dir");
    let trace = dir.join("trace.jsonl");

    // 1. + 3a. tracing off: identity across repeats, min-of-N timing.
    // Must run before telemetry::init — the recorder is once-per-process.
    assert!(!telemetry::enabled(), "telemetry must start disabled");
    let mut off_min_ms = f64::INFINITY;
    let mut sig_off = None;
    for _ in 0..TIMED_RUNS {
        let t = Instant::now();
        let r = co_optimize(&net, &space, &Table3, &cfg);
        off_min_ms = off_min_ms.min(t.elapsed().as_secs_f64() * 1e3);
        let s = signature(&r);
        match &sig_off {
            None => sig_off = Some(s),
            Some(first) => assert_eq!(&s, first, "untraced runs disagree"),
        }
    }
    let sig_off = sig_off.unwrap();
    println!(
        "perf_telemetry: tracing off: min {off_min_ms:.1} ms over {TIMED_RUNS} runs \
         (winner {}, {} full evals)",
        sig_off.winner,
        sig_off.evaluated_full
    );

    // 2. + 3b. tracing on: same bits, bounded overhead.
    telemetry::init(&trace, 7).expect("install recorder");
    assert!(telemetry::enabled());
    let mut on_min_ms = f64::INFINITY;
    for _ in 0..TIMED_RUNS {
        let t = Instant::now();
        let r = co_optimize(&net, &space, &Table3, &cfg);
        on_min_ms = on_min_ms.min(t.elapsed().as_secs_f64() * 1e3);
        assert_eq!(
            signature(&r),
            sig_off,
            "tracing changed the computation — telemetry must observe, never steer"
        );
    }
    let overhead = on_min_ms / off_min_ms;
    println!(
        "perf_telemetry: tracing on: min {on_min_ms:.1} ms, overhead {overhead:.3}x \
         (bound {MAX_OVERHEAD}x)"
    );
    assert!(
        overhead <= MAX_OVERHEAD,
        "tracing overhead {overhead:.3}x exceeds the {MAX_OVERHEAD}x bound \
         ({on_min_ms:.1} ms on vs {off_min_ms:.1} ms off)"
    );

    // Snapshot the trace before the fleet phase: only the co-opt runs
    // have written, so the engine roll-up gauges are exact fixtures
    // (the fleet remapper's own searches would otherwise mix in).
    telemetry::flush();
    let (coopt_records, _) = telemetry::read_trace(&trace).expect("read co-opt trace");
    let gauges = |plane: &str, name: &str| -> Vec<f64> {
        coopt_records
            .iter()
            .filter(|r| r.kind == "g")
            .filter(|r| r.json.get("plane").and_then(|v| v.as_str().ok()) == Some(plane))
            .filter(|r| r.json.get("name").and_then(|v| v.as_str().ok()) == Some(name))
            .filter_map(|r| r.json.get("val").and_then(|v| v.as_f64().ok()))
            .collect()
    };
    let stage2_totals = gauges("engine", "stage2_total");
    let stage3_totals = gauges("engine", "stage3_total");
    assert_eq!(stage2_totals.len(), TIMED_RUNS, "one stage2_total gauge per traced run");
    assert!(
        stage2_totals.iter().all(|&v| v == sig_off.engine_stage2 as f64),
        "stage2_total gauges {stage2_totals:?} disagree with the untraced run's {}",
        sig_off.engine_stage2
    );
    assert!(
        stage3_totals.iter().all(|&v| v == sig_off.engine_stage3 as f64),
        "stage3_total gauges {stage3_totals:?} disagree with the untraced run's {}",
        sig_off.engine_stage3
    );
    // Stage-3 share of stage-2 survivors — deterministic at threads = 1,
    // so the trajectory gates it like any other exact fixture.
    let stage3_pct = 100.0 * sig_off.engine_stage3 as f64 / sig_off.engine_stage2.max(1) as f64;

    // 4. one traced fleet scenario (in-process threads share this
    // recorder): mix-flip drives drift → replan → epoch adoption plus
    // per-batch spans and the merged latency-histogram event.
    let outcome = run_scenario(Scenario::MixFlip, 2, &dir.join("fleet"), None)
        .expect("traced mix-flip scenario");
    assert_eq!(outcome.stats.digest, outcome.baseline_digest, "traced digest moved");
    telemetry::flush();

    let (records, skipped) = telemetry::read_trace(&trace).expect("read trace");
    let summary = check_trace(&records, skipped);
    assert!(
        summary.violations.is_empty(),
        "trace violations:\n  {}",
        summary.violations.join("\n  ")
    );
    assert_eq!(summary.skipped, 0, "clean single-process trace has no torn lines");
    for plane in ["engine", "search", "fleet"] {
        assert!(
            summary.planes.iter().any(|p| p == plane),
            "plane `{plane}` missing from the trace (got {:?})",
            summary.planes
        );
    }
    let plane_count = |plane: &str| -> u64 {
        records
            .iter()
            .filter(|r| r.json.get("plane").and_then(|v| v.as_str().ok()) == Some(plane))
            .count() as u64
    };

    let hist = merged_latency_hist(&records);
    assert!(hist.count() > 0, "traced fleet scenario produced no latency-histogram events");
    let p99_ms = hist.quantile(99.0);
    println!(
        "perf_telemetry: trace {} records, planes [{}], stage3/stage2 {stage3_pct:.1}%, \
         fleet p99 {p99_ms:.3} ms over {} samples",
        summary.records,
        summary.planes.join(", "),
        hist.count()
    );

    let fields: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("perf_telemetry")),
        ("network".into(), Json::str("mlp-m")),
        ("timed_runs".into(), Json::int(TIMED_RUNS as u64)),
        ("coopt_off_min_ms".into(), Json::num(off_min_ms)),
        ("coopt_on_min_ms".into(), Json::num(on_min_ms)),
        ("telemetry_overhead_ratio".into(), Json::num(overhead)),
        ("signature_match".into(), Json::Bool(true)),
        ("trace_records".into(), Json::int(summary.records as u64)),
        ("trace_spans".into(), Json::int(summary.spans as u64)),
        ("trace_violations".into(), Json::int(summary.violations.len() as u64)),
        ("records_engine".into(), Json::int(plane_count("engine"))),
        ("records_search".into(), Json::int(plane_count("search"))),
        ("records_fleet".into(), Json::int(plane_count("fleet"))),
        ("span_engine_stage3_pct".into(), Json::num(stage3_pct)),
        ("fleet_batch_p99_ms_hist".into(), Json::num(p99_ms)),
        ("fleet_hist_count".into(), Json::int(hist.count())),
    ];
    interstellar::bench::emit(fields).expect("emit perf trajectory");
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "perf_telemetry OK (tracing-on bits identical, {overhead:.3}x overhead within \
         {MAX_OVERHEAD}x, trace schema-valid with zero orphaned spans)"
    );
}
