//! End-to-end serving bench: latency/throughput of the PJRT artifact
//! registry under the multi-worker request loop (the L3 request path).
//! Skips cleanly when `artifacts/` has not been built.

use std::path::Path;

use interstellar::coordinator::serve::{mixed_trace, serve};
use interstellar::search::default_threads;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("serve_e2e SKIPPED: run `make artifacts` first");
        return;
    }
    for threads in [1, 2, default_threads()] {
        let stats = serve(dir, mixed_trace(120, 99), threads).expect("serve");
        println!(
            "bench serve/mixed_trace threads={threads:<2} mean {:>7.3} ms  p95 {:>7.3} ms  {:>7.1} req/s",
            stats.mean_latency_ms, stats.p95_latency_ms, stats.rps
        );
    }
    // determinism: same trace, same checksum
    let a = serve(dir, mixed_trace(40, 5), 2).unwrap();
    let b = serve(dir, mixed_trace(40, 5), 4).unwrap();
    assert!(
        (a.checksum - b.checksum).abs() < 1e-3 * a.checksum.abs().max(1.0),
        "serving must be deterministic across worker counts"
    );
    println!("serve_e2e OK (deterministic across worker counts)");
}
