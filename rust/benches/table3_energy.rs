//! Table 3: the energy cost table (anchors + interpolation) and the cost
//! model's lookup throughput.

use interstellar::coordinator::experiments;
use interstellar::energy::{CostModel, Table3};
use interstellar::util::bench::{black_box, Bencher};

fn main() {
    println!("=== Table 3: energy per 16-bit access ===");
    print!("{}", experiments::table3().to_text());

    let mut b = Bencher::new(200);
    let m = Table3;
    b.bench("table3/reg_access_lookup", || {
        for s in [8u64, 16, 64, 512] {
            black_box(m.reg_access(black_box(s)));
        }
    });
    b.bench("table3/sram_access_lookup", || {
        for s in [32u64 << 10, 256 << 10, 28 << 20] {
            black_box(m.sram_access(black_box(s)));
        }
    });
}
