//! §Perf: network-level branch-and-bound effectiveness. Runs the §6.3
//! hierarchy co-optimization twice on the same design space — network-
//! level exhaustive (every architecture point fully evaluated, the old
//! `search_hierarchy` behavior) and cross-architecture branch-and-bound
//! (shared incumbent + compulsory-floor bound + seeded layer searches) —
//! and asserts the netopt winner-identity contract: the winning
//! (architecture, per-layer mappings) pair is **identical** while
//! strictly fewer architecture points are fully evaluated. Emits
//! `BENCH_netopt.json` for the perf trajectory.

use interstellar::arch::ArrayShape;
use interstellar::energy::Table3;
use interstellar::netopt::{co_optimize, DesignSpace, NetOptConfig};
use interstellar::nn::network;
use interstellar::search::SearchOpts;
use interstellar::util::bench::Bencher;
use interstellar::util::json::Json;

fn main() {
    // mlp-m: three distinct FC shapes whose DRAM-dominated floors make
    // the network bound bite; threads = 1 keeps candidate order (and so
    // the pruning trace) deterministic.
    let net = network("mlp-m", 32).unwrap();
    let space = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
    let mut opts = SearchOpts::capped(400, 5);
    opts.max_order_combos = 9;

    let mut b = Bencher::new(1);
    let mut ex = None;
    let m_ex = b.bench("perf_netopt/mlp-m exhaustive", || {
        ex = Some(co_optimize(
            &net,
            &space,
            &Table3,
            &NetOptConfig::exhaustive(opts.clone(), 1),
        ));
    });
    let mut bb = None;
    let m_bb = b.bench("perf_netopt/mlp-m b&b", || {
        bb = Some(co_optimize(
            &net,
            &space,
            &Table3,
            &NetOptConfig::new(opts.clone(), 1),
        ));
    });
    let ex = ex.expect("exhaustive ran");
    let bb = bb.expect("b&b ran");

    // winner-identity contract: same architecture, bit-identical energy,
    // identical per-layer mappings
    let we = ex.best().expect("exhaustive found a feasible winner");
    let wb = bb.best().expect("b&b found a feasible winner");
    assert_eq!(we.arch.name, wb.arch.name, "winner arch differs");
    assert_eq!(
        we.opt.total_energy_pj, wb.opt.total_energy_pj,
        "winner energy differs"
    );
    assert_eq!(we.opt.unmapped, 0);
    for (le, lb) in we.opt.per_layer.iter().zip(wb.opt.per_layer.iter()) {
        let (le, lb) = (le.as_ref().unwrap(), lb.as_ref().unwrap());
        assert_eq!(le.mapping, lb.mapping, "winner mapping differs");
        assert_eq!(le.result.energy_pj, lb.result.energy_pj);
    }

    // acceptance: strictly fewer fully evaluated architecture points
    assert_eq!(ex.stats.evaluated_full, ex.stats.candidates);
    assert_eq!(
        bb.stats.pruned + bb.stats.evaluated_full,
        bb.stats.candidates
    );
    assert!(
        bb.stats.evaluated_full < ex.stats.evaluated_full,
        "b&b must fully evaluate strictly fewer arch points ({} vs {})",
        bb.stats.evaluated_full,
        ex.stats.evaluated_full
    );

    println!("\n=== perf_netopt: architecture points, exhaustive vs branch-and-bound ===");
    println!(
        "candidates {}  full(exhaustive) {}  full(b&b) {}  pruned {}  seed reruns {}",
        bb.stats.candidates,
        ex.stats.evaluated_full,
        bb.stats.evaluated_full,
        bb.stats.pruned,
        bb.stats.layer_reruns
    );
    println!(
        "engine full evals: {} (exhaustive) vs {} (b&b)",
        ex.stats.engine.full, bb.stats.engine.full
    );

    let fields: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("perf_netopt")),
        ("network".into(), Json::str("mlp-m")),
        ("batch".into(), Json::int(32)),
        ("candidates".into(), Json::int(bb.stats.candidates as u64)),
        (
            "full_exhaustive".into(),
            Json::int(ex.stats.evaluated_full as u64),
        ),
        ("full_bnb".into(), Json::int(bb.stats.evaluated_full as u64)),
        ("pruned_bnb".into(), Json::int(bb.stats.pruned as u64)),
        ("seed_reruns".into(), Json::int(bb.stats.layer_reruns as u64)),
        (
            "engine_full_exhaustive".into(),
            Json::int(ex.stats.engine.full),
        ),
        ("engine_full_bnb".into(), Json::int(bb.stats.engine.full)),
        ("winner".into(), Json::str(&wb.arch.name)),
        ("winner_energy_pj".into(), Json::num(wb.opt.total_energy_pj)),
        ("mean_ns_exhaustive".into(), Json::num(m_ex.mean_ns)),
        ("mean_ns_bnb".into(), Json::num(m_bb.mean_ns)),
    ];
    interstellar::bench::emit(fields).expect("emit perf trajectory");
    println!("perf_netopt OK (identical winner, strictly fewer fully evaluated arch points)");
}
