//! §Perf: microbenchmarks of the L3 hot path — the few operations every
//! sweep, search, and serving remap ultimately spends its wall-clock in.
//! Unlike the contract gates (`perf_search` … `perf_orchestrator`) this
//! bench asserts nothing; it exists purely to feed stable timing slugs
//! into the perf trajectory so hot-path drift is visible *between* PRs
//! even when every contract still holds. The cases, innermost first:
//!
//! 1. `evaluate_one_mapping` — one full analytical-model evaluation
//!    ([`interstellar::xmodel::evaluate`]), the cost unit every "full
//!    evaluation" counter in the gates is denominated in.
//! 2. `engine_energy_bounded` (no bound / tight bound) — the staged
//!    engine's scalar path, which is what the search inner loop actually
//!    runs; the tight-bound case shows how much stage-3 early exit
//!    saves.
//! 3. `engine_footprints` — stage 2 alone: the fit check that gates
//!    every candidate before any energy work.
//! 4. `enumerate_blockings` — candidate generation at a 2000 cap: the
//!    per-search fixed cost that pruning cannot remove.
//! 5. `optimize_layer` at 1 thread vs N — the end-to-end per-layer
//!    search, exposing thread-scaling regressions.
//!
//! Emits `BENCH_hotpath.json` and appends to `bench_history.jsonl` via
//! [`interstellar::bench::emit`]; one `<case>_mean_ns` metric per case
//! (slugs via [`interstellar::bench::slug`]), all gated by
//! `bench-report --check` against their own history (see
//! BENCHMARKS.md).

use interstellar::arch::eyeriss_like;
use interstellar::coordinator::experiments;
use interstellar::dataflow::Dataflow;
use interstellar::energy::Table3;
use interstellar::engine::{Engine, EvalStats};
use interstellar::search::{
    divisor_replication, enumerate_blockings, optimize_layer, SearchOpts,
};
use interstellar::util::bench::{black_box, Bencher};
use interstellar::util::json::Json;
use interstellar::xmodel::evaluate;
use interstellar::loopnest::{Blocking, LevelOrder, Mapping, Tensor};

fn main() {
    let shape = experiments::alexnet_conv3(4);
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let smap = divisor_replication(&shape, &df, &arch.array);
    let spatial = smap.factors();
    let opts = SearchOpts::capped(2000, 6);

    let mut b = Bencher::new(400);

    // 1. single model evaluation (the innermost hot op)
    let tables = enumerate_blockings(&shape, &arch, spatial, &opts);
    let orders = vec![LevelOrder::stationary_for(Tensor::Output); arch.num_levels()];
    let mapping = Mapping {
        shape,
        blocking: Blocking {
            factors: tables[tables.len() / 2].clone(),
        },
        orders,
        spatial,
        spatial_at: arch.rf_levels(),
    };
    b.bench("perf/evaluate_one_mapping", || {
        black_box(evaluate(black_box(&mapping), &smap, &arch, &Table3).unwrap());
    });

    // 1b. the staged engine's scalar path (shared footprints, no
    // ModelResult allocation) — what the search's inner loop actually runs
    let engine = Engine::new(&arch, &Table3);
    let ctx = engine.context(&shape, &smap);
    let stats = EvalStats::default();
    let fp = engine.footprints(&mapping, &stats).expect("fits");
    let full = engine
        .energy_bounded(&mapping, &smap, &ctx, &fp, f64::INFINITY, &stats)
        .energy()
        .expect("completes");
    b.bench("perf/engine_energy_bounded (no bound)", || {
        black_box(engine.energy_bounded(
            black_box(&mapping),
            &smap,
            &ctx,
            &fp,
            f64::INFINITY,
            &stats,
        ));
    });
    b.bench("perf/engine_energy_bounded (tight bound)", || {
        black_box(engine.energy_bounded(
            black_box(&mapping),
            &smap,
            &ctx,
            &fp,
            full * 0.5,
            &stats,
        ));
    });
    b.bench("perf/engine_footprints (stage 2)", || {
        black_box(engine.footprints(black_box(&mapping), &stats).is_ok());
    });

    // 2. blocking enumeration
    b.bench("perf/enumerate_blockings(2000 cap)", || {
        black_box(enumerate_blockings(&shape, &arch, spatial, &opts));
    });

    // 3. end-to-end per-layer optimization, 1 thread vs N threads
    let small_opts = SearchOpts::capped(600, 5);
    b.bench("perf/optimize_layer conv3 (1 thread)", || {
        black_box(optimize_layer(&shape, &arch, &df, &Table3, &small_opts, 1));
    });
    let n = interstellar::search::default_threads();
    b.bench(&format!("perf/optimize_layer conv3 ({n} threads)"), || {
        black_box(optimize_layer(&shape, &arch, &df, &Table3, &small_opts, n));
    });

    // Flat scalar fields per the bench schema: one `<case>_mean_ns` per
    // measurement, case names slugged to JSON-key-friendly form.
    let mut fields: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("perf_hotpath")),
        ("cases".into(), Json::int(b.results().len() as u64)),
    ];
    for m in b.results() {
        let slug = interstellar::bench::slug(&m.name);
        fields.push((format!("{slug}_mean_ns"), Json::num(m.mean_ns)));
    }
    interstellar::bench::emit(fields).expect("emit perf trajectory");
    println!("\nperf_hotpath done (trajectory in BENCH_hotpath.json)");
}
