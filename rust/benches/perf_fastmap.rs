//! §Perf/CI gate: the microsecond heuristic mapper (`fastmap`).
//! Asserts the fast-path contracts on the paper workloads (AlexNet head,
//! lstm-m, mlp-m) and measures the heuristic against the exact search:
//!
//! 1. **Latency** — the aggregate per-layer heuristic latency over the
//!    suite's unique shapes is at least 100x below the per-layer
//!    branch-and-bound search at full CLI effort (`capped(20_000, 8)`).
//! 2. **Quality** — per workload, the best heuristic plan over the
//!    paper design-space candidates lands within 5% of the exact
//!    `co_optimize` winner's energy on the same candidates.
//! 3. **Priming** — scout priming (`NetOptConfig::prime`) leaves the
//!    `co_optimize` winner and the pareto frontier bit-identical while
//!    strictly reducing fully-evaluated mappings on `co_optimize`
//!    (never increasing them on `pareto`).
//!
//! Emits `BENCH_fastmap.json` for the perf trajectory (validated — and
//! required — by the `bench_schema` gate).

use interstellar::arch::{eyeriss_like, ArrayShape};
use interstellar::dataflow::Dataflow;
use interstellar::energy::Table3;
use interstellar::engine::DivisorCache;
use interstellar::fastmap::{heuristic_layer, heuristic_network};
use interstellar::loopnest::Shape;
use interstellar::netopt::{co_optimize, DesignSpace, NetOptConfig};
use interstellar::nn::{network, Network};
use interstellar::pareto::{pareto_optimize, ParetoConfig};
use interstellar::search::{optimize_layer, SearchOpts};
use interstellar::util::bench::{black_box, Bencher};
use interstellar::util::json::Json;

/// The paper workloads the fast path is graded on.
fn suite() -> Vec<Network> {
    vec![
        network("alexnet", 4).expect("alexnet").head(3),
        network("lstm-m", 1).expect("lstm-m"),
        network("mlp-m", 32).expect("mlp-m"),
    ]
}

/// Unique layer shapes across the whole suite (the heuristic and the
/// exact search both dedup by shape, so this is the honest unit of
/// per-layer work).
fn unique_shapes(nets: &[Network]) -> Vec<Shape> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for net in nets {
        for l in &net.layers {
            if seen.insert((l.shape.bounds, l.shape.stride)) {
                out.push(l.shape);
            }
        }
    }
    out
}

/// The shared per-layer search effort of the gap/priming comparisons —
/// CLI fast effort with the heuristic's own order cap so the exact side
/// stays affordable in CI.
fn gap_opts() -> SearchOpts {
    let mut opts = SearchOpts::capped(400, 5);
    opts.max_order_combos = 9;
    opts
}

fn main() {
    let mut b = Bencher::new(200);
    let mut fields: Vec<(String, Json)> = vec![("bench".into(), Json::str("perf_fastmap"))];
    let nets = suite();
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").expect("C|K");
    let shapes = unique_shapes(&nets);
    assert!(shapes.len() >= 6, "suite lost its layer diversity");

    // 1. per-layer latency: heuristic vs full-effort b&b, aggregated
    // over every unique shape in the suite
    let m_heur = b.bench("perf_fastmap/heuristic all layers", || {
        let mut cache = DivisorCache::new();
        for s in &shapes {
            black_box(heuristic_layer(s, &arch, &df, &Table3, &mut cache));
        }
    });
    let full = SearchOpts::capped(20_000, 8);
    let t0 = std::time::Instant::now();
    for s in &shapes {
        black_box(optimize_layer(s, &arch, &df, &Table3, &full, 1));
    }
    let bnb_ns = t0.elapsed().as_nanos() as f64;
    let speedup = bnb_ns / m_heur.mean_ns.max(1.0);
    assert!(
        speedup >= 100.0,
        "heuristic is only {speedup:.0}x faster than full-effort b&b \
         (heur {} ns, b&b {} ns over {} shapes)",
        m_heur.mean_ns,
        bnb_ns,
        shapes.len()
    );
    fields.push(("unique_shapes".into(), Json::int(shapes.len() as u64)));
    fields.push(("mean_ns_heuristic_suite".into(), Json::num(m_heur.mean_ns)));
    fields.push(("ns_bnb_suite".into(), Json::num(bnb_ns)));
    fields.push(("layer_speedup".into(), Json::num(speedup)));

    // 2. energy gap per workload: best heuristic plan over the paper
    // candidates vs the exact co-optimizer on the same candidates
    let space = DesignSpace::paper_default(ArrayShape { rows: 16, cols: 16 });
    let cands = space.enumerate().candidates;
    assert!(!cands.is_empty(), "paper space enumerated empty");
    for net in &nets {
        let cfg = NetOptConfig::new(gap_opts(), 1);
        let exact = co_optimize(net, &space, &Table3, &cfg);
        let ew = exact.best().expect("exact winner").opt.total_energy_pj;
        let mut cache = DivisorCache::new();
        let eh = cands
            .iter()
            .map(|a| heuristic_network(net, a, &df, &Table3, None, &mut cache))
            .filter(|o| o.unmapped == 0)
            .map(|o| o.total_energy_pj)
            .fold(f64::INFINITY, f64::min);
        assert!(eh.is_finite(), "{}: no feasible heuristic plan", net.name);
        let gap = eh / ew - 1.0;
        assert!(
            gap <= 0.05,
            "{}: heuristic energy gap {:.2}% exceeds 5% (heur {eh}, exact {ew})",
            net.name,
            gap * 100.0
        );
        let slug = interstellar::bench::slug(&net.name);
        fields.push((format!("gap_pct_{slug}"), Json::num(gap * 100.0)));
    }

    // 3a. scout priming on co_optimize (mlp-m): bit-identical winner,
    // strictly fewer fully-evaluated mappings
    let mlp = &nets[2];
    let cfg_off = NetOptConfig::new(gap_opts(), 1);
    let cfg_on = NetOptConfig::new(gap_opts(), 1).with_prime(true);
    let off = co_optimize(mlp, &space, &Table3, &cfg_off);
    let on = co_optimize(mlp, &space, &Table3, &cfg_on);
    let (wo, wn) = (off.best().expect("off"), on.best().expect("on"));
    assert_eq!(wo.arch, wn.arch, "priming moved the winner arch");
    assert_eq!(
        wo.opt.total_energy_pj.to_bits(),
        wn.opt.total_energy_pj.to_bits(),
        "priming moved the winner energy bits"
    );
    for (x, y) in wo.opt.per_layer.iter().zip(wn.opt.per_layer.iter()) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.mapping, y.mapping, "priming moved a winner mapping");
        assert_eq!(x.result, y.result, "priming moved a winner result");
    }
    assert!(
        on.stats.engine.full < off.stats.engine.full,
        "priming did not reduce full evaluations ({} >= {})",
        on.stats.engine.full,
        off.stats.engine.full
    );
    fields.push(("co_opt_full_unprimed".into(), Json::int(off.stats.engine.full)));
    fields.push(("co_opt_full_primed".into(), Json::int(on.stats.engine.full)));

    // 3b. scout priming on pareto (lstm-m): bit-identical frontier,
    // never more full evaluations
    let lstm = &nets[1];
    let pcfg = ParetoConfig::default();
    let poff = pareto_optimize(lstm, &space, &Table3, &cfg_off, &pcfg);
    let pon = pareto_optimize(lstm, &space, &Table3, &cfg_on, &pcfg);
    assert_eq!(poff.frontier.len(), pon.frontier.len(), "frontier size moved");
    for (a, c) in poff.frontier.iter().zip(pon.frontier.iter()) {
        assert_eq!(a.index, c.index, "priming moved a frontier index");
        assert_eq!(a.result.arch, c.result.arch, "priming moved a frontier arch");
        assert_eq!(
            a.result.opt.total_energy_pj.to_bits(),
            c.result.opt.total_energy_pj.to_bits(),
            "priming moved frontier energy bits"
        );
        assert_eq!(
            a.result.opt.total_cycles.to_bits(),
            c.result.opt.total_cycles.to_bits(),
            "priming moved frontier cycle bits"
        );
    }
    assert!(
        pon.stats.engine.full <= poff.stats.engine.full,
        "priming increased pareto full evaluations ({} > {})",
        pon.stats.engine.full,
        poff.stats.engine.full
    );
    fields.push(("pareto_full_unprimed".into(), Json::int(poff.stats.engine.full)));
    fields.push(("pareto_full_primed".into(), Json::int(pon.stats.engine.full)));
    fields.push(("frontier_points".into(), Json::int(poff.frontier.len() as u64)));

    interstellar::bench::emit(fields).expect("emit perf trajectory");
    println!(
        "perf_fastmap OK ({}x over full-effort b&b, gaps within 5%, priming \
         bit-identical with fewer full evaluations)",
        speedup as u64
    );
}
