//! Fig 14: the efficient auto-optimizer across all nine benchmarks.
//! Paper's claims: up to 3.5x/2.7x/4.2x energy gains for VGG-16 /
//! GoogLeNet / MobileNet, ~1.6x for LSTMs, ~1.8x for MLPs, vs the
//! Eyeriss-like baseline; plus TOPS/W in the 0.35–1.85 band.

use interstellar::coordinator::experiments::{self, Effort};
use interstellar::search::default_threads;
use interstellar::util::bench::Bencher;

fn main() {
    let threads = default_threads();
    let mut b = Bencher::new(1);
    let mut table = None;
    b.bench("fig14/auto_optimizer 9 benchmarks", || {
        table = Some(experiments::fig14_optimizer(Effort::Fast, threads));
    });
    let table = table.unwrap();
    println!("\n=== Fig 14: auto-optimizer gains ===");
    print!("{}", table.to_text());

    let csv = table.to_csv();
    let gain = |net: &str| -> f64 {
        csv.lines()
            .find(|l| l.starts_with(net))
            .map(|l| {
                l.split(',')
                    .nth(3)
                    .unwrap()
                    .trim_end_matches('x')
                    .parse::<f64>()
                    .unwrap()
            })
            .unwrap_or_else(|| panic!("{net} row missing"))
    };
    // shape assertions: meaningful CNN gains, smaller LSTM/MLP gains
    for net in ["vgg16", "googlenet", "mobilenet"] {
        let g = gain(net);
        println!("{net}: {g:.2}x (paper: 2.7x-4.2x)");
        assert!(g > 1.3, "{net} gain {g:.2}x too small");
    }
    for net in ["lstm-m", "lstm-l", "rhn", "mlp-m", "mlp-l"] {
        let g = gain(net);
        println!("{net}: {g:.2}x (paper: ~1.6x-1.8x; DRAM-bound so bounded)");
        assert!(g >= 0.99, "{net} optimizer must not lose to the baseline");
    }
    // crossover shape: CNN gains exceed LSTM/MLP gains
    let cnn_best = ["vgg16", "googlenet", "mobilenet"]
        .iter()
        .map(|n| gain(n))
        .fold(0.0, f64::max);
    let rec_best = ["lstm-m", "mlp-m"].iter().map(|n| gain(n)).fold(0.0, f64::max);
    assert!(
        cnn_best > rec_best,
        "CNN gains ({cnn_best:.2}x) should exceed LSTM/MLP gains ({rec_best:.2}x)"
    );
    println!("\nfig14 OK");
}
