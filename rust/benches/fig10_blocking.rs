//! Fig 10: the loop-blocking design space for AlexNet CONV3 with C|K on
//! the 512 B-RF configuration. The paper's claim: blocking spreads energy
//! far more than dataflow — only ~30% of schemes land within 1.25x of
//! the minimum.

use interstellar::coordinator::experiments::{self, Effort};
use interstellar::search::default_threads;
use interstellar::util::bench::Bencher;

fn main() {
    let threads = default_threads();
    let shape = experiments::alexnet_conv3(4);
    let mut b = Bencher::new(1);

    let mut table = None;
    b.bench("fig10/blocking_sweep conv3", || {
        table = Some(experiments::fig10_blocking(shape, Effort::Fast, threads));
    });
    let table = table.unwrap();
    println!("\n=== Fig 10: blocking design space (AlexNet CONV3, C|K, 512 B RF) ===");
    print!("{}", table.to_text());

    // claims: wide spread; a minority of schemes near-optimal
    let csv = table.to_csv();
    let get = |key: &str| -> f64 {
        csv.lines()
            .find(|l| l.starts_with(key))
            .and_then(|l| {
                l.split(',')
                    .nth(1)
                    .map(|v| v.trim_end_matches(['x', '%']).parse::<f64>().unwrap())
            })
            .unwrap_or_else(|| panic!("row {key} missing"))
    };
    let spread = get("max / min");
    let near_opt = get("% within 1.25x of min");
    assert!(spread > 2.0, "blocking spread {spread}x should be wide");
    assert!(
        near_opt < 60.0,
        "only a minority should be near-optimal, got {near_opt}%"
    );
    println!("\nfig10 OK (blocking matters much more than dataflow)");
}
