//! §Perf: staged-engine branch-and-bound effectiveness on the per-layer
//! search. For AlexNet conv layers, runs the same blocking × order search
//! twice — exhaustive (every candidate fully evaluated, the seed's
//! behavior) and branch-and-bound (stage-2/3 lower bounds against a
//! shared incumbent) — and asserts the pruning contract: the winning
//! mapping is **identical**, while full (stage-4) evaluations drop by at
//! least 3x. Emits `BENCH_search.json` for the perf trajectory
//! (validated by the `bench_schema` gate; see BENCHMARKS.md).

use interstellar::arch::eyeriss_like;
use interstellar::bench::slug;
use interstellar::dataflow::Dataflow;
use interstellar::energy::Table3;
use interstellar::engine::PruneMode;
use interstellar::nn::network;
use interstellar::search::{optimize_layer, SearchOpts};
use interstellar::util::bench::Bencher;
use interstellar::util::json::Json;
use interstellar::util::table::Table;

fn main() {
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let net = network("alexnet", 4).unwrap();
    let conv_layers: Vec<_> = net
        .layers
        .iter()
        .filter(|l| l.name.starts_with("CONV"))
        .collect();
    assert!(conv_layers.len() >= 3, "need at least 3 conv layers");

    let mut b = Bencher::new(1);
    let mut t = Table::new(vec![
        "layer",
        "candidates",
        "full (exhaustive)",
        "full (b&b)",
        "reduction",
        "pruned@bound",
    ]);
    let mut reductions = Vec::new();
    let mut fields: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("perf_search")),
        ("layers".into(), Json::int(conv_layers.len() as u64)),
    ];

    for layer in &conv_layers {
        let ex_opts = SearchOpts::capped(800, 5).with_prune(PruneMode::Exhaustive);
        let bb_opts = SearchOpts::capped(800, 5).with_prune(PruneMode::BranchAndBound);

        // threads = 1: deterministic candidate order in both modes
        let mut ex = None;
        b.bench(&format!("perf_search/{} exhaustive", layer.name), || {
            ex = optimize_layer(&layer.shape, &arch, &df, &Table3, &ex_opts, 1);
        });
        let mut bb = None;
        b.bench(&format!("perf_search/{} b&b", layer.name), || {
            bb = optimize_layer(&layer.shape, &arch, &df, &Table3, &bb_opts, 1);
        });
        let ex = ex.expect("exhaustive found a mapping");
        let bb = bb.expect("b&b found a mapping");

        // pruning contract: identical winner, bit-for-bit
        assert_eq!(
            ex.result.energy_pj, bb.result.energy_pj,
            "{}: b&b energy differs from exhaustive",
            layer.name
        );
        assert_eq!(
            ex.mapping, bb.mapping,
            "{}: b&b winner mapping differs from exhaustive",
            layer.name
        );

        let reduction = ex.stats.full as f64 / bb.stats.full.max(1) as f64;
        reductions.push(reduction);
        let ls = slug(&layer.name);
        fields.push((format!("full_exhaustive_{ls}"), Json::int(ex.stats.full)));
        fields.push((format!("full_bnb_{ls}"), Json::int(bb.stats.full)));
        fields.push((format!("reduction_{ls}"), Json::num(reduction)));
        t.row(vec![
            layer.name.clone(),
            format!("{}", ex.evaluated),
            format!("{}", ex.stats.full),
            format!("{}", bb.stats.full),
            format!("{reduction:.1}x"),
            format!("{}", bb.stats.pruned),
        ]);
    }

    println!("\n=== perf_search: full evaluations, exhaustive vs branch-and-bound ===");
    print!("{}", t.to_text());

    // acceptance: >=3x fewer full (stage-4) evaluations on >=3 layers,
    // at identical winning mappings (asserted above)
    let at_least_3x = reductions.iter().filter(|&&r| r >= 3.0).count();
    println!(
        "\nlayers with >=3x fewer full evaluations: {}/{}",
        at_least_3x,
        reductions.len()
    );
    assert!(
        at_least_3x >= 3,
        "expected >=3x reduction on at least 3 layers, got {reductions:?}"
    );

    fields.push(("layers_at_least_3x".into(), Json::int(at_least_3x as u64)));
    for m in b.results() {
        fields.push((format!("{}_mean_ns", slug(&m.name)), Json::num(m.mean_ns)));
    }
    interstellar::bench::emit(fields).expect("emit perf trajectory");
    println!("perf_search OK (identical winners, >=3x fewer full evaluations)");
}
