//! §Perf/CI gate: multi-process shard equivalence. For each fixture
//! (small design space × {alexnet head, lstm-m, mlp-m}) this bench
//!
//! 1. runs the single-process `co_optimize` reference in-process,
//! 2. spawns `NSHARDS` **separate OS processes** of the release binary,
//!    each running `co-opt --shard I/N --checkpoint PATH` over the same
//!    space,
//! 3. merges their checkpoint files with a `co-opt-merge` process, and
//! 4. asserts the cross-process contract: the merged winner is
//!    **bit-identical** to the single-process winner (architecture,
//!    energy bits, per-layer mappings), the checkpoint merge is
//!    associative and order-free, and the merged stats satisfy the
//!    `NetOptStats` partition identities.
//!
//! Emits `BENCH_shard.json` for the perf trajectory (validated by the
//! `bench_schema` gate).

use std::path::Path;
use std::process::Command;
use std::time::Instant;

use interstellar::arch::ArrayShape;
use interstellar::energy::Table3;
use interstellar::netopt::{
    co_optimize, merge_all, merge_checkpoints, DesignSpace, NetOptConfig, ShardCheckpoint,
};
use interstellar::nn::{network, Network};
use interstellar::search::SearchOpts;
use interstellar::util::bench::Bencher;
use interstellar::util::json::Json;

const NSHARDS: usize = 3;
const THREADS: usize = 2;

/// Must mirror `space_cli_args` exactly — the in-process reference and
/// the worker processes sweep the same space.
fn small_space() -> DesignSpace {
    let mut s = DesignSpace::paper_default(ArrayShape { rows: 8, cols: 8 });
    s.rf1_sizes = vec![16, 64, 512];
    s.rf2_ratios = vec![8];
    s.gbuf_sizes = vec![64 << 10, 256 << 10];
    s.ratio_min = 0.25;
    s.ratio_max = 64.0;
    s
}

/// Must mirror the `--cap/--divisors/--orders` CLI args below.
fn small_opts() -> SearchOpts {
    let mut o = SearchOpts::capped(150, 4);
    o.max_order_combos = 9;
    o
}

/// CLI flags reproducing `small_space()` + `small_opts()` for the worker
/// processes.
fn space_cli_args() -> Vec<String> {
    let flags = "--rows 8 --cols 8 --rf1 16,64,512 --rf2-ratio 8 --gbuf 65536,262144 \
                 --ratio-min 0.25 --ratio-max 64 --cap 150 --divisors 4 --orders 9 --threads 2";
    flags.split_whitespace().map(str::to_string).collect()
}

struct Fixture {
    /// Filesystem/JSON-key-safe label.
    label: &'static str,
    net: Network,
    /// Network-selection CLI flags for the worker processes.
    cli: &'static [&'static str],
}

fn fixtures() -> Vec<Fixture> {
    vec![
        Fixture {
            label: "alexnet_head3",
            net: network("alexnet", 1).unwrap().head(3),
            cli: &["--net", "alexnet", "--batch", "1", "--head", "3"],
        },
        Fixture {
            label: "lstm_m",
            net: network("lstm-m", 1).unwrap(),
            cli: &["--net", "lstm-m", "--batch", "1"],
        },
        Fixture {
            label: "mlp_m",
            net: network("mlp-m", 16).unwrap(),
            cli: &["--net", "mlp-m", "--batch", "16"],
        },
    ]
}

fn read_checkpoint(path: &Path) -> ShardCheckpoint {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    ShardCheckpoint::from_json(&text)
        .unwrap_or_else(|e| panic!("parsing {}: {e}", path.display()))
}

fn main() {
    let bin = env!("CARGO_BIN_EXE_interstellar");
    let dir = std::env::temp_dir().join(format!("interstellar-perf-shard-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");

    let mut b = Bencher::new(1);
    let mut bench_fields: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("perf_shard")),
        ("nshards".into(), Json::int(NSHARDS as u64)),
        ("fixtures".into(), Json::int(fixtures().len() as u64)),
    ];

    for fx in fixtures() {
        // 1. single-process reference (identical config to the workers)
        let mut single = None;
        let m_single = b.bench(&format!("perf_shard/{} single-process", fx.label), || {
            single = Some(co_optimize(
                &fx.net,
                &small_space(),
                &Table3,
                &NetOptConfig::new(small_opts(), THREADS),
            ));
        });
        let single = single.expect("single-process run");

        // 2. N concurrent worker processes, one shard each
        let t0 = Instant::now();
        let mut children = Vec::new();
        let mut paths = Vec::new();
        for i in 0..NSHARDS {
            let path = dir.join(format!("{}_{i}.json", fx.label));
            let child = Command::new(bin)
                .arg("co-opt")
                .args(fx.cli)
                .args(space_cli_args())
                .arg("--shard")
                .arg(format!("{i}/{NSHARDS}"))
                .arg("--checkpoint")
                .arg(&path)
                .spawn()
                .unwrap_or_else(|e| panic!("spawning worker {i}: {e}"));
            children.push((i, child));
            paths.push(path);
        }
        for (i, mut child) in children {
            let status = child.wait().expect("waiting for worker");
            assert!(status.success(), "{}: worker {i} failed: {status}", fx.label);
        }
        let workers_ns = t0.elapsed().as_nanos() as f64;

        // 3. merge in a separate process
        let merged_path = dir.join(format!("{}_merged.json", fx.label));
        let status = Command::new(bin)
            .arg("co-opt-merge")
            .args(&paths)
            .arg("--out")
            .arg(&merged_path)
            .status()
            .expect("running co-opt-merge");
        assert!(status.success(), "{}: co-opt-merge failed: {status}", fx.label);
        let merged = read_checkpoint(&merged_path);

        // 4a. cross-process winner identity, bit for bit: architecture,
        // network totals, and every per-layer (mapping, smap, result).
        // Search counters are excluded — pruning histories legitimately
        // differ across process layouts; the optimum must not.
        let sw = single.best().expect("single-process winner");
        let mw = merged.winner_result().expect("merged winner");
        assert_eq!(sw.arch, mw.arch, "{}: winner arch differs", fx.label);
        assert_eq!(
            sw.opt.total_energy_pj.to_bits(),
            mw.opt.total_energy_pj.to_bits(),
            "{}: winner energy bits differ ({} vs {})",
            fx.label,
            sw.opt.total_energy_pj,
            mw.opt.total_energy_pj
        );
        assert_eq!(
            sw.opt.total_cycles.to_bits(),
            mw.opt.total_cycles.to_bits(),
            "{}: winner cycle bits differ",
            fx.label
        );
        assert_eq!(sw.opt.total_macs, mw.opt.total_macs);
        assert_eq!(sw.opt.unmapped, 0);
        assert_eq!(mw.opt.unmapped, 0);
        assert_eq!(sw.opt.per_layer.len(), mw.opt.per_layer.len());
        for (x, y) in sw.opt.per_layer.iter().zip(mw.opt.per_layer.iter()) {
            let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
            assert_eq!(x.mapping, y.mapping, "{}: winner mapping differs", fx.label);
            assert_eq!(x.smap, y.smap, "{}: winner smap differs", fx.label);
            assert_eq!(x.result, y.result, "{}: winner result differs", fx.label);
        }

        // 4b. the merge is associative and order-free, and the separate
        // merge process agrees with the in-process merge
        let ckpts: Vec<ShardCheckpoint> = paths.iter().map(|p| read_checkpoint(p)).collect();
        let left =
            merge_checkpoints(&merge_checkpoints(&ckpts[0], &ckpts[1]).unwrap(), &ckpts[2])
                .unwrap();
        let right =
            merge_checkpoints(&ckpts[0], &merge_checkpoints(&ckpts[1], &ckpts[2]).unwrap())
                .unwrap();
        let reversed =
            merge_all(&[ckpts[2].clone(), ckpts[1].clone(), ckpts[0].clone()]).unwrap();
        assert_eq!(left, right, "{}: merge not associative", fx.label);
        assert_eq!(left, reversed, "{}: merge not order-free", fx.label);
        assert_eq!(left, merged, "{}: process merge diverges", fx.label);

        // 4c. merged stats identities
        assert!(
            merged.stats.invariants_hold(),
            "{}: merged stats break invariants: {}",
            fx.label,
            merged.stats
        );
        assert_eq!(merged.shards, (0..NSHARDS).collect::<Vec<_>>());
        assert_eq!(merged.stats.generated, single.stats.generated);
        assert_eq!(merged.stats.candidates, single.stats.candidates);

        println!(
            "perf_shard/{}: winner {} ({} uJ) identical across {} processes",
            fx.label,
            mw.arch.name,
            mw.opt.total_energy_pj / 1e6,
            NSHARDS
        );
        bench_fields.push((format!("{}_winner", fx.label), Json::str(&mw.arch.name)));
        bench_fields.push((
            format!("{}_winner_energy_pj", fx.label),
            Json::num(mw.opt.total_energy_pj),
        ));
        bench_fields.push((
            format!("{}_candidates", fx.label),
            Json::int(merged.stats.candidates as u64),
        ));
        bench_fields.push((
            format!("{}_evaluated_full", fx.label),
            Json::int(merged.stats.evaluated_full as u64),
        ));
        bench_fields.push((
            format!("{}_mean_ns_single", fx.label),
            Json::num(m_single.mean_ns),
        ));
        bench_fields.push((
            format!("{}_ns_workers_e2e", fx.label),
            Json::num(workers_ns),
        ));
    }

    interstellar::bench::emit(bench_fields).expect("emit perf trajectory");
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "perf_shard OK ({NSHARDS}-process winners bit-identical to single-process, \
         merge associative)"
    );
}
