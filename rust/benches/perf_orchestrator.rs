//! §Perf/CI gate: the distributed sweep orchestrator. Over one fixture
//! (alexnet head-2 on the small `--space full` grid) this bench drives
//! [`orchestrate`] against the release binary and asserts the
//! orchestrator contract:
//!
//! 1. **Scaling** — the same 8-shard sweep run with 1, 2, and 4 worker
//!    processes (1 thread each, bound streaming off so this measures
//!    pure fan-out) completes near-linearly: >= 2.5x wall-clock speedup
//!    at 4 workers.
//! 2. **Bit identity** — the 4-worker merged winner is bit-identical to
//!    the in-process `co_optimize` reference, and the 4-worker merged
//!    frontier is payload-bit-identical to in-process
//!    `pareto_optimize`, streaming on in both cases.
//! 3. **Bound streaming saves work** — with 2 workers over 8 shards
//!    (4 sequential waves), aggregate full evaluations with live bound
//!    streaming on are **strictly** fewer than with it off: later waves
//!    start from earlier shards' published incumbents instead of cold.
//! 4. **Crash tolerance** — with 1 of 4 workers SIGKILLed mid-run and
//!    work stealing on, the sweep still completes with full coverage
//!    and the same winner bits (the victim's shard is re-split and
//!    redistributed; duplicate coverage deduplicates in the merge).
//!
//! Emits `BENCH_orchestrator.json` for the perf trajectory (validated
//! by the `bench_schema` gate).

use std::path::Path;
use std::time::{Duration, Instant};

use interstellar::arch::ArrayShape;
use interstellar::energy::Table3;
use interstellar::netopt::{co_optimize, DesignSpace, NetOptConfig};
use interstellar::nn::{network, Network};
use interstellar::orchestrator::{orchestrate, MergedSweep, OrchestrateConfig, SweepMode};
use interstellar::pareto::{pareto_optimize, ParetoConfig};
use interstellar::search::SearchOpts;
use interstellar::util::json::Json;

const NSHARDS: usize = 8;

/// Must mirror `worker_args()` exactly — the in-process references and
/// the worker processes sweep the same space with the same caps.
fn bench_space() -> DesignSpace {
    let mut s = DesignSpace::full(ArrayShape { rows: 8, cols: 8 });
    s.rf1_sizes = vec![16, 64, 512];
    s.rf2_ratios = vec![8];
    s.gbuf_sizes = vec![64 << 10, 256 << 10];
    s.ratio_min = 0.25;
    s.ratio_max = 64.0;
    s
}

/// Must mirror the `--cap/--divisors/--orders` worker args below.
fn bench_opts() -> SearchOpts {
    let mut o = SearchOpts::capped(150, 4);
    o.max_order_combos = 9;
    o
}

fn bench_net() -> Network {
    network("alexnet", 1).unwrap().head(2)
}

/// Worker CLI flags reproducing `bench_net()` + `bench_space()` +
/// `bench_opts()`. Single-threaded workers (`--threads 1`) so the
/// scaling curve measures process fan-out, not intra-process
/// parallelism; `--no-prime` so the streaming comparison starts every
/// worker cold (the scout would otherwise hand each one a near-optimal
/// private bound and mask the cross-worker savings).
fn worker_args() -> Vec<String> {
    let flags = "--net alexnet --batch 1 --head 2 --space full --rows 8 --cols 8 \
                 --rf1 16,64,512 --rf2-ratio 8 --gbuf 65536,262144 \
                 --ratio-min 0.25 --ratio-max 64 --cap 150 --divisors 4 --orders 9 \
                 --threads 1 --no-prime";
    flags.split_whitespace().map(str::to_string).collect()
}

fn base_config(bin: &str, dir: &Path, workers: usize) -> OrchestrateConfig {
    let mut cfg = OrchestrateConfig::new(SweepMode::CoOpt, bin, dir, workers);
    cfg.nshards = NSHARDS;
    cfg.worker_args = worker_args();
    cfg.bounds_interval = None;
    cfg
}

fn assert_winner_bits(
    merged: &MergedSweep,
    reference: &interstellar::search::HierarchyResult,
    label: &str,
) {
    let MergedSweep::CoOpt(ckpt) = merged else {
        panic!("{label}: expected a co-opt merge");
    };
    assert_eq!(
        ckpt.shards,
        (0..ckpt.nshards).collect::<Vec<_>>(),
        "{label}: merged coverage incomplete"
    );
    let w = ckpt.winner_result().expect("merged winner");
    assert_eq!(w.arch, reference.arch, "{label}: winner arch differs");
    assert_eq!(
        w.opt.total_energy_pj.to_bits(),
        reference.opt.total_energy_pj.to_bits(),
        "{label}: winner energy bits differ ({} vs {})",
        w.opt.total_energy_pj,
        reference.opt.total_energy_pj
    );
    assert_eq!(
        w.opt.total_cycles.to_bits(),
        reference.opt.total_cycles.to_bits(),
        "{label}: winner cycle bits differ"
    );
}

fn main() {
    let bin = env!("CARGO_BIN_EXE_interstellar");
    let dir =
        std::env::temp_dir().join(format!("interstellar-perf-orch-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create scratch dir");

    let net = bench_net();
    let space = bench_space();
    let cfg = NetOptConfig::new(bench_opts(), 2).with_prime(false);

    // In-process references (bit-identity targets).
    let t0 = Instant::now();
    let reference = co_optimize(&net, &space, &Table3, &cfg);
    let single_ms = t0.elapsed().as_secs_f64() * 1e3;
    let ref_winner = reference.best().expect("reference winner").clone();
    let pareto_ref = pareto_optimize(
        &net,
        &space,
        &Table3,
        &cfg,
        &ParetoConfig {
            eps: 0.0,
            max_points: None,
        },
    );

    // 1. scaling curve: 1 / 2 / 4 workers, streaming off.
    let mut walls_ms = Vec::new();
    let mut evals_off_2w = 0usize;
    for workers in [1usize, 2, 4] {
        let ocfg = base_config(bin, &dir.join(format!("w{workers}")), workers);
        let t = Instant::now();
        let report = orchestrate(&ocfg)
            .unwrap_or_else(|e| panic!("orchestrate with {workers} workers: {e}"));
        let wall_ms = t.elapsed().as_secs_f64() * 1e3;
        assert_winner_bits(&report.merged, &ref_winner, &format!("{workers}-worker"));
        assert_eq!(report.failures, 0, "{workers}-worker run had failures");
        println!(
            "perf_orchestrator: {workers} workers over {NSHARDS} shards: {wall_ms:.0} ms \
             ({} full evals)",
            report.aggregate_evaluated_full
        );
        if workers == 2 {
            evals_off_2w = report.aggregate_evaluated_full;
        }
        walls_ms.push(wall_ms);
    }
    let speedup_4w = walls_ms[0] / walls_ms[2];
    assert!(
        speedup_4w >= 2.5,
        "4-worker speedup {speedup_4w:.2}x below the 2.5x gate \
         (walls: {walls_ms:.0?} ms)"
    );

    // 2. bound streaming strictly reduces aggregate full evaluations.
    // 2 workers over 8 shards = 4 sequential waves, so later waves are
    // ordering-guaranteed (not timing-dependent) to see earlier shards'
    // final published bounds.
    let mut ocfg = base_config(bin, &dir.join("stream"), 2);
    ocfg.bounds_interval = Some(Duration::from_millis(10));
    let report = orchestrate(&ocfg).expect("streaming run");
    let evals_on_2w = report.aggregate_evaluated_full;
    assert_winner_bits(&report.merged, &ref_winner, "streaming");
    assert!(
        evals_on_2w < evals_off_2w,
        "bound streaming did not reduce full evaluations ({evals_on_2w} vs {evals_off_2w})"
    );
    println!(
        "perf_orchestrator: streaming on {evals_on_2w} vs off {evals_off_2w} full evals \
         (same winner bits)"
    );

    // 3. crash tolerance: SIGKILL worker seq 1 shortly after launch;
    // stealing re-splits its shard and the sweep completes with full
    // coverage and the same winner.
    let mut ocfg = base_config(bin, &dir.join("kill"), 4);
    ocfg.bounds_interval = Some(Duration::from_millis(10));
    ocfg.fault_kill = Some((1, Duration::from_millis(5)));
    let killed = orchestrate(&ocfg).expect("fault-injected run");
    assert_winner_bits(&killed.merged, &ref_winner, "fault-injected");
    assert!(
        killed.failures >= 1,
        "fault injection killed no worker (victim finished too fast?)"
    );
    assert!(
        killed.steals >= 1,
        "killed worker's shard was not re-split and stolen"
    );
    println!(
        "perf_orchestrator: survived SIGKILL of 1/4 workers ({} failures, {} steals, \
         {} launched)",
        killed.failures, killed.steals, killed.launched
    );

    // 4. pareto mode: merged 4-worker frontier payload-bit-identical to
    // the in-process frontier (checkpoints key by raw-grid index, the
    // in-process result by filtered position — payloads are the
    // contract, as in perf_pareto).
    let mut ocfg = base_config(bin, &dir.join("pareto"), 4);
    ocfg.mode = SweepMode::Pareto;
    ocfg.bounds_interval = Some(Duration::from_millis(10));
    let report = orchestrate(&ocfg).expect("pareto orchestrate");
    let MergedSweep::Pareto(merged) = &report.merged else {
        panic!("expected a pareto merge");
    };
    assert_eq!(
        merged.frontier.len(),
        pareto_ref.frontier.len(),
        "frontier size differs from in-process pareto"
    );
    for ((_, m), e) in merged.frontier.iter().zip(pareto_ref.frontier.iter()) {
        assert_eq!(m.arch, e.result.arch, "frontier arch differs");
        assert_eq!(
            m.opt.total_energy_pj.to_bits(),
            e.result.opt.total_energy_pj.to_bits(),
            "frontier energy bits differ"
        );
        assert_eq!(
            m.opt.total_cycles.to_bits(),
            e.result.opt.total_cycles.to_bits(),
            "frontier cycle bits differ"
        );
    }
    println!(
        "perf_orchestrator: 4-worker pareto frontier bit-identical ({} points)",
        merged.frontier.len()
    );

    let fields: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("perf_orchestrator")),
        ("nshards".into(), Json::int(NSHARDS as u64)),
        ("single_process_ms".into(), Json::num(single_ms)),
        ("wall_1w_ms".into(), Json::num(walls_ms[0])),
        ("wall_2w_ms".into(), Json::num(walls_ms[1])),
        ("wall_4w_ms".into(), Json::num(walls_ms[2])),
        ("speedup_4w".into(), Json::num(speedup_4w)),
        ("evals_bounds_off_2w".into(), Json::int(evals_off_2w as u64)),
        ("evals_bounds_on_2w".into(), Json::int(evals_on_2w as u64)),
        ("kill_failures".into(), Json::int(killed.failures as u64)),
        ("kill_steals".into(), Json::int(killed.steals as u64)),
        ("kill_launched".into(), Json::int(killed.launched as u64)),
        (
            "pareto_frontier_points".into(),
            Json::int(merged.frontier.len() as u64),
        ),
        ("winner".into(), Json::str(&ref_winner.arch.name)),
        (
            "winner_energy_pj".into(),
            Json::num(ref_winner.opt.total_energy_pj),
        ),
    ];
    interstellar::bench::emit(fields).expect("emit perf trajectory");
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "perf_orchestrator OK ({speedup_4w:.2}x at 4 workers, streaming {evals_on_2w}<{evals_off_2w} \
         full evals, SIGKILL survived, winners/frontiers bit-identical)"
    );
}
