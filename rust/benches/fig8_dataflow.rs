//! Fig 8: the dataflow design space. For AlexNet CONV3 and GoogLeNet
//! 4C3R (batch 16-equivalent and batch 1), the energy of every dataflow
//! (with replication + optimal blocking) on the three hardware
//! configurations. The paper's claim: the spread across dataflows is
//! small once blocking is optimized, and the small-RF config wins.

use interstellar::coordinator::experiments::{self, Effort};
use interstellar::search::default_threads;
use interstellar::util::bench::Bencher;

fn main() {
    let threads = default_threads();
    let effort = Effort::Fast;
    let mut b = Bencher::new(1);

    for (name, shape) in experiments::spotlight_layers(effort) {
        let mut table = None;
        b.bench(&format!("fig8/sweep {name}"), || {
            table = Some(experiments::fig8_dataflow(shape, effort, threads));
        });
        println!("\n=== Fig 8: {name} ===");
        print!("{}", table.unwrap().to_text());

        let spreads = experiments::fig8_spread(shape, effort, threads);
        for (arch, spread, med) in &spreads {
            println!(
                "  {arch}: max/min = {spread:.2}x, median/min = {med:.2}x across dataflows"
            );
        }
        // Observation 1, quantified: with optimal blocking the *typical*
        // dataflow lands near the optimum. The broadcast-bus config is
        // the paper's own counter-illustration (no inter-PE reuse), so it
        // gets a looser bound.
        for (arch, spread, med) in &spreads {
            if arch == "broadcast-bus" {
                assert!(*spread < 8.0, "{arch}: spread {spread:.2}x");
            } else {
                assert!(*med < 1.8, "{arch}: median/min {med:.2}x too wide");
                assert!(*spread < 3.0, "{arch}: spread {spread:.2}x too wide");
            }
        }
    }
    println!("\nfig8 OK (dataflow choice is secondary to blocking)");
}
