//! Cross-module integration tests: schedule DSL → lowering → model →
//! simulator → search all composing on real layer shapes.

use interstellar::arch::{eyeriss_like, optimized_mobile, small_rf, validation_designs};
use interstellar::dataflow::{enumerate_dataflows, Dataflow};
use interstellar::energy::Table3;
use interstellar::halide::{eyeriss_rs, tpu_ck};
use interstellar::loopnest::{Shape, Tensor, ALL_TENSORS};
use interstellar::nn::{all_benchmarks, network};
use interstellar::search::{
    divisor_replication, optimize_layer, optimize_network, SearchOpts,
};
use interstellar::sim::{count_rounds, functional_conv, reference_conv, simulate, ConvData};
use interstellar::util::prop;
use interstellar::xmodel::{evaluate, RoundTables};

fn fast_opts() -> SearchOpts {
    SearchOpts::capped(400, 5)
}

#[test]
fn every_benchmark_layer_is_optimizable_on_eyeriss() {
    // Every layer of every benchmark must admit at least one feasible
    // mapping on the Eyeriss-like config with C|K.
    let df = Dataflow::parse("C|K").unwrap();
    let arch = eyeriss_like();
    for net in all_benchmarks() {
        let mut seen = std::collections::HashSet::new();
        for layer in &net.layers {
            if !seen.insert((layer.shape.bounds, layer.shape.stride)) {
                continue;
            }
            let lo = optimize_layer(&layer.shape, &arch, &df, &Table3, &fast_opts(), 2);
            assert!(
                lo.is_some(),
                "{} / {} has no feasible mapping",
                net.name,
                layer.name
            );
        }
    }
}

#[test]
fn schedule_dsl_to_simulator_round_trip() {
    // DSL-authored schedules and the trace simulator agree bit-exactly on
    // energy for a mid-sized layer.
    let shape = Shape::new(2, 32, 16, 8, 8, 3, 3, 1);
    let arch = eyeriss_like();
    for (name, sched) in [
        ("tpu_ck", tpu_ck(shape, 16, 16)),
        ("eyeriss_rs", eyeriss_rs(shape, 16, 16)),
    ] {
        let (m, smap) = sched.lower(&arch).unwrap_or_else(|e| panic!("{name}: {e}"));
        let model = evaluate(&m, &smap, &arch, &Table3).unwrap();
        let sim = simulate(&m, &smap, &arch, &Table3, 500_000_000).unwrap();
        assert_eq!(
            model.energy_pj, sim.energy_pj,
            "{name}: model and simulator disagree"
        );
    }
}

#[test]
fn validation_designs_functionally_correct() {
    // Table-4 designs compute correct convolutions through the full
    // schedule machinery (functional mode).
    let shape = Shape::new(1, 8, 6, 5, 5, 3, 3, 1);
    for (arch, df_str) in validation_designs() {
        let df = Dataflow::parse(df_str).unwrap();
        let Some(lo) = optimize_layer(&shape, &arch, &df, &Table3, &fast_opts(), 2) else {
            panic!("{}: no mapping", arch.name);
        };
        let data = ConvData::random(shape, 31337);
        assert_eq!(
            functional_conv(&lo.mapping, &data),
            reference_conv(&data),
            "{}: functional mismatch",
            arch.name
        );
    }
}

#[test]
fn optimizer_beats_presets_on_conv3() {
    // The blocking search must do at least as well as the hand-written
    // preset schedules on the same hardware.
    let conv3 = Shape::new(4, 384, 256, 13, 13, 3, 3, 1);
    let arch = eyeriss_like();
    let (pm, psm) = tpu_ck(conv3, 16, 16).lower(&arch).unwrap();
    let preset = evaluate(&pm, &psm, &arch, &Table3).unwrap();
    let opt = optimize_layer(
        &conv3,
        &arch,
        &Dataflow::parse("C|K").unwrap(),
        &Table3,
        &fast_opts(),
        2,
    )
    .unwrap();
    assert!(
        opt.result.energy_pj <= preset.energy_pj,
        "search {} worse than preset {}",
        opt.result.energy_pj,
        preset.energy_pj
    );
}

#[test]
fn two_level_rf_hierarchy_evaluates() {
    // optimized_mobile has RF1+RF2: the 4-level path must work end to end
    let shape = Shape::new(2, 32, 32, 7, 7, 3, 3, 1);
    let arch = optimized_mobile();
    let df = Dataflow::parse("C|K").unwrap();
    let lo = optimize_layer(&shape, &arch, &df, &Table3, &fast_opts(), 2).expect("mapping");
    assert_eq!(lo.mapping.levels(), 4);
    assert_eq!(lo.mapping.spatial_at, 2);
    let sim = simulate(&lo.mapping, &lo.smap, &arch, &Table3, 500_000_000).unwrap();
    assert_eq!(lo.result.energy_pj, sim.energy_pj);
}

#[test]
fn prop_model_equals_sim_on_benchmark_shaped_layers() {
    // random mappings on real (scaled-down) benchmark layer shapes
    prop::for_cases(0x1f2e, 40, |rng| {
        let net = network("googlenet", 1).unwrap();
        let layer = &net.layers[rng.below(net.layers.len() as u64) as usize];
        // scale down spatial dims to keep the walk cheap
        let mut b = layer.shape.bounds;
        b[3] = b[3].min(4);
        b[4] = b[4].min(4);
        b[1] = b[1].min(32);
        b[2] = b[2].min(32);
        let shape = Shape {
            bounds: b,
            stride: layer.shape.stride,
        };
        let arch = small_rf();
        let (m, _smap) = interstellar::search::random_mapping_for_arch(shape, &arch, rng);
        let analytic = RoundTables::analytic(&m);
        if let Ok(exact) = count_rounds(&m, 20_000_000) {
            for t in ALL_TENSORS {
                for i in 0..m.levels() {
                    assert_eq!(
                        analytic.rounds[t.idx()][i],
                        exact.rounds[t.idx()][i],
                        "{t} boundary {i} on {}: {m:?}",
                        layer.name
                    );
                }
            }
        }
    });
}

#[test]
fn network_energy_accumulates_layer_energies() {
    let net = network("mlp-m", 16).unwrap();
    let df = Dataflow::parse("C|K").unwrap();
    let opt = optimize_network(&net, &eyeriss_like(), &df, &Table3, &fast_opts(), 2);
    let sum: f64 = opt
        .per_layer
        .iter()
        .flatten()
        .map(|lo| lo.result.energy_pj)
        .sum();
    assert!((opt.total_energy_pj - sum).abs() < 1e-9 * sum.max(1.0));
}

#[test]
fn dataflow_enumeration_all_evaluable_on_small_layer() {
    // every enumerated dataflow must be lowerable + evaluable
    let shape = Shape::new(2, 12, 12, 6, 6, 3, 3, 1);
    let arch = eyeriss_like();
    let mut evaluated = 0;
    for df in enumerate_dataflows(&shape) {
        let smap = divisor_replication(&shape, &df, &arch.array);
        if let Some(lo) = optimize_layer(&shape, &arch, &df, &Table3, &fast_opts(), 1) {
            assert!(lo.result.energy_pj > 0.0);
            assert_eq!(lo.smap.factors(), smap.factors());
            evaluated += 1;
        }
    }
    assert!(evaluated >= 15, "only {evaluated}/21 dataflows evaluable");
}

#[test]
fn output_accesses_bounded_by_compulsory_traffic() {
    // DRAM output writes can never be below the output size (compulsory)
    let shape = Shape::new(2, 16, 8, 6, 6, 3, 3, 1);
    let arch = eyeriss_like();
    let df = Dataflow::parse("C|K").unwrap();
    let lo = optimize_layer(&shape, &arch, &df, &Table3, &fast_opts(), 2).unwrap();
    let dram = lo.result.levels.last().unwrap();
    let out_words = shape.tensor_elems(Tensor::Output) as f64;
    assert!(dram.writes[Tensor::Output.idx()] >= out_words);
    let in_words = shape.tensor_elems(Tensor::Input) as f64;
    assert!(dram.reads[Tensor::Input.idx()] >= in_words);
}
