"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, block sizes, strides and dtypes; every case
asserts allclose against ref.py. This is the core correctness signal for
the compute layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    conv2d_tiled,
    depthwise_conv2d_tiled,
    matmul_tiled,
    pick_block,
    ref,
)

RNG = np.random.default_rng(1234)


def _arr(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


def _close(a, b, dtype=np.float32):
    if dtype == np.float32:
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    else:  # bf16
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-2
        )


# ---------------------------------------------------------------- pick_block


@given(dim=st.integers(1, 512), pref=st.integers(1, 512))
@settings(max_examples=200, deadline=None)
def test_pick_block_divides_and_bounded(dim, pref):
    b = pick_block(dim, pref)
    assert 1 <= b <= dim
    assert dim % b == 0
    assert b <= max(1, min(pref, dim))


def test_pick_block_exact():
    assert pick_block(128, 128) == 128
    assert pick_block(48, 32) == 24
    assert pick_block(7, 4) == 1
    assert pick_block(12, 6) == 6


# ------------------------------------------------------------------- matmul


@given(
    m=st.integers(1, 48),
    c=st.integers(1, 48),
    n=st.integers(1, 48),
    bm=st.sampled_from([1, 4, 8, 16, 128]),
    bn=st.sampled_from([1, 4, 8, 16, 128]),
    bc=st.sampled_from([1, 4, 8, 16, 128]),
)
@settings(max_examples=25, deadline=None)
def test_matmul_matches_ref(m, c, n, bm, bn, bc):
    a = _arr(m, c)
    b = _arr(c, n)
    out = matmul_tiled(a, b, block_m=bm, block_n=bn, block_c=bc)
    assert out.shape == (m, n)
    _close(out, ref.matmul_ref(a, b))


def test_matmul_bf16():
    a = _arr(32, 32, dtype=np.float32).astype(jnp.bfloat16)
    b = _arr(32, 32, dtype=np.float32).astype(jnp.bfloat16)
    out = matmul_tiled(a, b, block_m=8, block_n=8, block_c=8)
    assert out.dtype == jnp.bfloat16
    _close(out, ref.matmul_ref(a, b), dtype=np.float16)


def test_matmul_identity():
    a = _arr(16, 16)
    eye = jnp.eye(16, dtype=jnp.float32)
    _close(matmul_tiled(a, eye, block_m=4, block_n=4, block_c=4), a)


def test_matmul_block_larger_than_dim():
    a = _arr(3, 5)
    b = _arr(5, 2)
    _close(matmul_tiled(a, b, block_m=64, block_n=64, block_c=64), ref.matmul_ref(a, b))


# --------------------------------------------------------------------- conv


@given(
    b=st.integers(1, 3),
    x=st.integers(1, 9),
    c=st.integers(1, 12),
    k=st.integers(1, 12),
    f=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    bk=st.sampled_from([1, 4, 64]),
)
@settings(max_examples=20, deadline=None)
def test_conv_matches_ref(b, x, c, k, f, stride, bk):
    xh = (x - 1) * stride + f
    i = _arr(b, xh, xh, c)
    w = _arr(f, f, c, k)
    out = conv2d_tiled(i, w, stride=stride, block_k=bk)
    assert out.shape == (b, x, x, k)
    _close(out, ref.conv2d_ref(i, w, stride=stride))


def test_conv_rectangular_filter():
    i = _arr(1, 8, 10, 4)
    w = _arr(3, 5, 4, 6)
    _close(conv2d_tiled(i, w, block_k=2), ref.conv2d_ref(i, w))


def test_conv_1x1_equals_matmul():
    i = _arr(2, 6, 6, 8)
    w = _arr(1, 1, 8, 4)
    out = conv2d_tiled(i, w, block_k=4)
    mm = ref.matmul_ref(i.reshape(-1, 8), w[0, 0]).reshape(2, 6, 6, 4)
    _close(out, mm)


def test_conv_block_k_irregular():
    # K=6 with block_k preference 4 -> picks 3 (largest divisor <= 4)
    i = _arr(1, 6, 6, 3)
    w = _arr(3, 3, 3, 6)
    _close(conv2d_tiled(i, w, block_k=4), ref.conv2d_ref(i, w))


# ---------------------------------------------------------------- depthwise


@given(
    b=st.integers(1, 2),
    x=st.integers(1, 8),
    c=st.integers(1, 16),
    f=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    bc=st.sampled_from([1, 8, 128]),
)
@settings(max_examples=15, deadline=None)
def test_depthwise_matches_ref(b, x, c, f, stride, bc):
    xh = (x - 1) * stride + f
    i = _arr(b, xh, xh, c)
    w = _arr(f, f, c)
    out = depthwise_conv2d_tiled(i, w, stride=stride, block_c=bc)
    assert out.shape == (b, x, x, c)
    _close(out, ref.depthwise_conv2d_ref(i, w, stride=stride))


def test_depthwise_vs_grouped_conv():
    # depthwise == conv with a diagonal C->K filter bank
    i = _arr(1, 6, 6, 4)
    w = _arr(3, 3, 4)
    full = jnp.zeros((3, 3, 4, 4), jnp.float32)
    for ch in range(4):
        full = full.at[:, :, ch, ch].set(w[:, :, ch])
    _close(depthwise_conv2d_tiled(i, w), ref.conv2d_ref(i, full))


# -------------------------------------------------------------- determinism


def test_kernels_deterministic():
    a = _arr(24, 24)
    b = _arr(24, 24)
    o1 = matmul_tiled(a, b, block_m=8, block_n=8, block_c=8)
    o2 = matmul_tiled(a, b, block_m=8, block_n=8, block_c=8)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_blocking_invariance():
    """Different block choices must compute the same function (fp-tolerant).

    This is the kernel-level statement of the paper's premise: blocking
    changes locality, never semantics.
    """
    a = _arr(36, 30)
    b = _arr(30, 42)
    base = np.asarray(matmul_tiled(a, b, block_m=36, block_n=42, block_c=30))
    for bm, bn, bc in [(1, 1, 30), (4, 6, 5), (9, 14, 15), (36, 42, 1)]:
        out = np.asarray(matmul_tiled(a, b, block_m=bm, block_n=bn, block_c=bc))
        np.testing.assert_allclose(out, base, rtol=1e-4, atol=1e-5)
