"""AOT path: every artifact entry point lowers to valid HLO text and the
manifest format is what the Rust loader expects."""

import re

import jax
import numpy as np

from compile import aot


def test_all_artifacts_lower():
    for name, (fn, specs) in aot.ARTIFACTS.items():
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # text parser requirement: no 64-bit id syntax issues surface as
        # parse failures on the rust side; here we just sanity-check shape
        # annotations exist.
        assert re.search(r"f32\[", text), name


def test_artifact_outputs_are_tuples():
    # return_tuple=True on lowering; every fn returns a tuple so the rust
    # side can uniformly to_tuple() the result.
    for name, (fn, specs) in aot.ARTIFACTS.items():
        outs = jax.eval_shape(fn, *specs)
        assert isinstance(outs, tuple), name


def test_manifest_line_format():
    fn, specs = aot.ARTIFACTS["fc"]
    in_s = ";".join(aot._fmt(s) for s in specs)
    assert in_s == "f32[8,64];f32[64,32]"


def test_conv3x3_artifact_numerics():
    """Execute the lowered conv3x3 via jax and compare to the oracle —
    the same check the Rust runtime test performs through PJRT."""
    from compile.kernels import ref

    fn, specs = aot.ARTIFACTS["conv3x3"]
    rng = np.random.default_rng(7)
    args = [rng.normal(size=s.shape).astype(np.float32) for s in specs]
    (out,) = jax.jit(fn)(*args)
    np.testing.assert_allclose(
        out, ref.conv2d_ref(*args), rtol=1e-4, atol=1e-4
    )
