"""L2 correctness: layer-forward graphs vs oracles, shape contracts."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(99)


def _arr(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def _close(a, b):
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_conv_layer_shape_and_value():
    i = _arr(2, 10, 10, 8)
    w = _arr(3, 3, 8, 12)
    out = model.conv_layer(i, w, block_k=4)
    assert out.shape == (2, 8, 8, 12)
    _close(out, ref.conv2d_ref(i, w))


def test_pointwise_equals_1x1_conv():
    i = _arr(2, 7, 7, 16)
    w = _arr(16, 8)
    _close(model.pointwise_layer(i, w), ref.conv2d_ref(i, w[None, None]))


def test_depthwise_layer():
    i = _arr(1, 9, 9, 8)
    w = _arr(3, 3, 8)
    _close(model.depthwise_layer(i, w), ref.depthwise_conv2d_ref(i, w))


def test_fc_layer():
    a = _arr(16, 32)
    w = _arr(32, 10)
    _close(model.fc_layer(a, w), ref.matmul_ref(a, w))


@given(b=st.integers(1, 4), e=st.sampled_from([8, 16]), h=st.sampled_from([8, 16]))
@settings(max_examples=8, deadline=None)
def test_lstm_cell_matches_ref(b, e, h):
    x, hh, cc = _arr(b, e), _arr(b, h), _arr(b, h)
    w_ih, w_hh, bias = _arr(e, 4 * h), _arr(h, 4 * h), _arr(4 * h)
    hn, cn = model.lstm_cell(x, hh, cc, w_ih, w_hh, bias)
    hr, cr = ref.lstm_cell_ref(x, hh, cc, w_ih, w_hh, bias)
    assert hn.shape == (b, h) and cn.shape == (b, h)
    _close(hn, hr)
    _close(cn, cr)


def test_lstm_cell_state_bounded():
    # tanh(o * ...) => |h| <= 1 elementwise
    x, h, c = _arr(3, 16), _arr(3, 16), _arr(3, 16)
    w_ih, w_hh, bias = _arr(16, 64), _arr(16, 64), _arr(64)
    hn, _ = model.lstm_cell(x, h, c, w_ih, w_hh, bias)
    assert np.all(np.abs(np.asarray(hn)) <= 1.0 + 1e-6)


def test_conv_relu_chain_shape_preserved():
    i = _arr(1, 8, 8, 4)
    ws = [_arr(3, 3, 4, 8), _arr(3, 3, 8, 8)]
    out = model.conv_relu_chain(i, ws)
    assert out.shape == (1, 8, 8, 8)
    assert np.all(np.asarray(out) >= 0.0)  # relu output


def test_conv_relu_chain_matches_manual():
    i = _arr(1, 6, 6, 3)
    w1, w2 = _arr(3, 3, 3, 4), _arr(3, 3, 4, 4)
    out = model.conv_relu_chain(i, [w1, w2])
    pad = lambda t: jnp.pad(t, ((0, 0), (1, 1), (1, 1), (0, 0)))
    manual = jnp.maximum(ref.conv2d_ref(pad(i), w1), 0.0)
    manual = jnp.maximum(ref.conv2d_ref(pad(manual), w2), 0.0)
    _close(out, manual)
