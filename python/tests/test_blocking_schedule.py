"""Cross-layer contract: the Pallas kernels accept the block sizes the
Rust optimizer emits (exact divisors), and kernel tiling mirrors the
schedule semantics (any valid blocking computes the same function)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d_tiled, matmul_tiled, ref
from compile.kernels.matmul import vmem_words
from compile.kernels.conv import conv_vmem_words

RNG = np.random.default_rng(3)


def _arr(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def divisors(n):
    return [d for d in range(1, n + 1) if n % d == 0]


@given(data=st.data())
@settings(max_examples=12, deadline=None)
def test_matmul_any_exact_divisor_blocking(data):
    m = data.draw(st.sampled_from([8, 12, 24]))
    c = data.draw(st.sampled_from([6, 16, 18]))
    n = data.draw(st.sampled_from([4, 10, 32]))
    bm = data.draw(st.sampled_from(divisors(m)))
    bc = data.draw(st.sampled_from(divisors(c)))
    bn = data.draw(st.sampled_from(divisors(n)))
    a, b = _arr(m, c), _arr(c, n)
    out = matmul_tiled(a, b, block_m=bm, block_n=bn, block_c=bc)
    np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-5)


@given(bk=st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=5, deadline=None)
def test_conv_any_k_blocking(bk):
    i = _arr(1, 8, 8, 4)
    w = _arr(3, 3, 4, 16)
    out = conv2d_tiled(i, w, block_k=bk)
    np.testing.assert_allclose(out, ref.conv2d_ref(i, w), rtol=1e-4, atol=1e-4)


def test_vmem_estimates_fit_budget():
    # the shapes we AOT must fit a 16 MiB VMEM at f32
    budget_words = (16 << 20) // 4
    assert vmem_words(8, 64, 32, 128, 32, 128) < budget_words
    assert conv_vmem_words(2, 10, 10, 16, 3, 3, 32, 16) < budget_words


def test_vmem_grows_with_blocks():
    small = vmem_words(128, 128, 128, 32, 32, 32)
    large = vmem_words(128, 128, 128, 128, 128, 128)
    assert small < large
