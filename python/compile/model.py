"""L2: layer-forward compute graphs, calling the Pallas kernels.

Each public function here is a jit-able forward for one layer kind of the
paper's workload set (CONV, pointwise CONV, depthwise CONV, FC, LSTM
cell). `aot.py` lowers instances of these at the artifact shapes to HLO
text; the Rust runtime executes them on the PJRT CPU client.

Python is build-time only: nothing in this module runs on the request path.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import conv2d_tiled, depthwise_conv2d_tiled, matmul_tiled


def conv_layer(inp, w, *, stride=1, block_k=64):
    """CONV layer forward (pre-padded input), Pallas-tiled."""
    return conv2d_tiled(inp, w, stride=stride, block_k=block_k)


def pointwise_layer(inp, w, *, block_k=128):
    """1x1 CONV (e.g. GoogLeNet 4C3R) — lowers to a matmul over channels.

    inp: [B, X, Y, C]; w: [C, K] -> [B, X, Y, K].
    """
    b, x, y, c = inp.shape
    flat = inp.reshape(b * x * y, c)
    out = matmul_tiled(flat, w, block_m=128, block_n=block_k, block_c=128)
    return out.reshape(b, x, y, w.shape[1])


def depthwise_layer(inp, w, *, stride=1, block_c=128):
    """Depthwise CONV layer forward (MobileNet)."""
    return depthwise_conv2d_tiled(inp, w, stride=stride, block_c=block_c)


def fc_layer(inp, w, *, block_n=128):
    """FC layer forward: [B, C] @ [C, K]."""
    return matmul_tiled(inp, w, block_m=128, block_n=block_n, block_c=128)


def lstm_cell(x, h, c, w_ih, w_hh, bias):
    """LSTM cell forward; both gate matmuls go through the Pallas kernel."""
    gates = (
        matmul_tiled(x, w_ih).astype(jnp.float32)
        + matmul_tiled(h, w_hh).astype(jnp.float32)
        + bias.astype(jnp.float32)
    )
    hdim = h.shape[-1]
    i = lax.logistic(gates[:, 0 * hdim : 1 * hdim])
    f = lax.logistic(gates[:, 1 * hdim : 2 * hdim])
    g = jnp.tanh(gates[:, 2 * hdim : 3 * hdim])
    o = lax.logistic(gates[:, 3 * hdim : 4 * hdim])
    c_next = f * c.astype(jnp.float32) + i * g
    h_next = o * jnp.tanh(c_next)
    return h_next.astype(h.dtype), c_next.astype(c.dtype)


def conv_relu_chain(inp, ws, *, stride=1):
    """A small CONV->ReLU stack (the e2e driver's mini AlexNet tail).

    ws: list of [FX,FY,C,K] weights; each conv is VALID over a freshly
    padded input so spatial size is preserved.
    """
    out = inp
    for w in ws:
        fx, fy = w.shape[0], w.shape[1]
        px, py = fx // 2, fy // 2
        out = jnp.pad(out, ((0, 0), (px, px), (py, py), (0, 0)))
        out = conv_layer(out, w, stride=stride)
        out = jax.nn.relu(out)
    return out
