"""AOT lowering: JAX/Pallas entry points -> HLO text artifacts.

Runs ONCE at build time (`make artifacts`); the Rust runtime loads the
HLO text via `HloModuleProto::from_text_file` and executes it on the PJRT
CPU client. Python never runs on the request path.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifact shapes are intentionally small: they are the *functional* stand-in
for the scheduled hardware — the Rust coordinator cross-checks its trace
simulator's conv outputs against these, and serves batched layer requests
through them in the e2e example. A plain-text manifest (one line per
artifact: name, file, input/output dtypes+shapes) lets the Rust side load
everything without a JSON parser.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt(s):
    return f"f32[{','.join(str(d) for d in s.shape)}]"


# ---------------------------------------------------------------------------
# Artifact registry: name -> (fn returning a tuple, input specs)
# Shapes mirror (at reduced scale) the paper's workloads:
#   conv3x3   — AlexNet CONV3-like CONV layer (the Fig 8a/10 subject)
#   conv1x1   — GoogLeNet 4C3R-like pointwise reduction (Fig 8c subject)
#   conv5x5_s2— strided large-filter CONV (AlexNet CONV1/2 family)
#   depthwise — MobileNet depthwise layer
#   fc        — MLP / FC layer (Fig 11 FC bars)
#   lstm_cell — seq2seq LSTM cell (LSTM-M/L family)
#   conv_chain— two stacked CONV+ReLU layers: the e2e driver's model
# ---------------------------------------------------------------------------


def _conv3x3(i, w):
    return (model.conv_layer(i, w, stride=1, block_k=16),)


def _conv1x1(i, w):
    return (model.pointwise_layer(i, w, block_k=16),)


def _conv5x5_s2(i, w):
    return (model.conv_layer(i, w, stride=2, block_k=8),)


def _depthwise(i, w):
    return (model.depthwise_layer(i, w, stride=1, block_c=8),)


def _fc(a, b):
    return (model.fc_layer(a, b, block_n=32),)


def _lstm_cell(x, h, c, w_ih, w_hh, bias):
    return model.lstm_cell(x, h, c, w_ih, w_hh, bias)


def _conv_chain(i, w1, w2):
    return (model.conv_relu_chain(i, [w1, w2]),)


ARTIFACTS = {
    "conv3x3": (
        _conv3x3,
        [_spec(2, 10, 10, 16), _spec(3, 3, 16, 32)],
    ),
    "conv1x1": (
        _conv1x1,
        [_spec(2, 8, 8, 32), _spec(32, 16)],
    ),
    "conv5x5_s2": (
        _conv5x5_s2,
        [_spec(1, 13, 13, 8), _spec(5, 5, 8, 16)],
    ),
    "depthwise": (
        _depthwise,
        [_spec(2, 10, 10, 16), _spec(3, 3, 16)],
    ),
    "fc": (
        _fc,
        [_spec(8, 64), _spec(64, 32)],
    ),
    "lstm_cell": (
        _lstm_cell,
        [
            _spec(4, 32),
            _spec(4, 32),
            _spec(4, 32),
            _spec(32, 128),
            _spec(32, 128),
            _spec(128),
        ],
    ),
    "conv_chain": (
        _conv_chain,
        [_spec(1, 8, 8, 8), _spec(3, 3, 8, 16), _spec(3, 3, 16, 16)],
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output dir (or a single .hlo.txt path for the default artifact)")
    args = ap.parse_args()

    out_dir = args.out
    if out_dir.endswith(".hlo.txt"):
        out_dir = os.path.dirname(out_dir) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest_lines = []
    for name, (fn, specs) in sorted(ARTIFACTS.items()):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        in_s = ";".join(_fmt(s) for s in specs)
        out_s = ";".join(_fmt(s) for s in outs)
        manifest_lines.append(f"name={name} file={fname} inputs={in_s} outputs={out_s}")
        print(f"  {name}: {len(text)} chars, in=[{in_s}] out=[{out_s}]")

    # `model.hlo.txt` is the Makefile's stamp target: the conv_chain e2e model.
    import shutil

    shutil.copyfile(
        os.path.join(out_dir, "conv_chain.hlo.txt"),
        os.path.join(out_dir, "model.hlo.txt"),
    )
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(ARTIFACTS)} artifacts + manifest to {out_dir}")


if __name__ == "__main__":
    main()
