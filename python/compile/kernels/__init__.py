"""L1: Pallas kernels + pure-jnp oracles for the Interstellar stack."""

from .conv import conv2d_tiled, depthwise_conv2d_tiled  # noqa: F401
from .matmul import matmul_tiled, pick_block  # noqa: F401
from . import ref  # noqa: F401
