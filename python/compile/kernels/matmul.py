"""Blocking-parameterized Pallas matmul kernel.

This is the FC / LSTM-gate hot-spot (the paper's C,K,B-only loop nest) as a
Pallas kernel. The (block_m, block_n, block_c) tiling is exactly the loop
blocking the Interstellar schedule language produces for the array level:
the grid is the outer (Mo, No, Co) loops, each kernel body is one inner
tile's worth of MACs on the MXU.

TPU adaptation notes (see DESIGN.md §Hardware-Adaptation):
  - block shapes default to MXU-friendly multiples (8, 128);
    VMEM footprint per grid step is
    block_m*block_c + block_c*block_n + block_m*block_n words.
  - the C (reduction) grid dimension is innermost so the output tile stays
    resident across the accumulation — "output stationary at the array
    level" in the paper's taxonomy (dataflow C|K maps C,K to the grid).
  - interpret=True everywhere in this repo: the CPU PJRT plugin cannot run
    Mosaic custom-calls; numerics are identical to the TPU lowering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (block_m, block_n) output tile; accumulates over the C grid dim.

    The output block index is independent of the C grid index, so o_ref
    aliases the same tile across the reduction — the canonical Pallas
    accumulate-in-place pattern.
    """
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...],
        b_ref[...],
        preferred_element_type=o_ref.dtype,
    ).astype(o_ref.dtype)


def pick_block(dim, preferred):
    """Largest divisor of `dim` that is <= preferred (tiles must divide)."""
    b = max(1, min(preferred, dim))
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_c", "interpret")
)
def matmul_tiled(a, b, *, block_m=128, block_n=128, block_c=128, interpret=True):
    """Tiled matmul: [M, C] @ [C, N] -> [M, N], f32 accumulation.

    Block sizes are clamped to the largest divisor of each dim so any shape
    works; schedules produced by the Rust optimizer pass exact divisors.
    """
    m, c = a.shape
    c2, n = b.shape
    assert c == c2, f"contraction mismatch {c} vs {c2}"
    bm = pick_block(m, block_m)
    bn = pick_block(n, block_n)
    bc = pick_block(c, block_c)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, c // bc),
        in_specs=[
            pl.BlockSpec((bm, bc), lambda i, j, k: (i, k)),
            pl.BlockSpec((bc, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out.astype(a.dtype)


def vmem_words(m, c, n, block_m, block_n, block_c):
    """VMEM working-set estimate (words) for one grid step — used by the
    DESIGN.md roofline discussion and checked by tests against the 16 MiB
    VMEM budget for the shapes we AOT."""
    bm = pick_block(m, block_m)
    bn = pick_block(n, block_n)
    bc = pick_block(c, block_c)
    return bm * bc + bc * bn + bm * bn
