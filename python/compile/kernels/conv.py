"""Blocking-parameterized Pallas CONV kernel.

The paper's seven-loop CONV nest, tiled the way the Interstellar schedule
language tiles it for hardware: the Pallas grid carries the outer (B, Ko)
loops, the kernel body walks the filter taps and performs one
(X*Y, C) @ (C, Tk) MXU matmul per tap — i.e. the `C | K` dataflow with the
spatial dims replicated onto the MXU rows (DESIGN.md §Hardware-Adaptation).

Layouts: I [B, XH, YH, C] (pre-padded, XH=(X-1)*stride+FX), W [FX,FY,C,K],
O [B, X, Y, K]. The weight tile for one grid step is (FX, FY, C, Tk) and
the input block is one full padded image — for the layer shapes we AOT
this fits the 16 MiB VMEM budget (asserted in tests via `vmem_words`).

interpret=True throughout: CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _conv_kernel(i_ref, w_ref, o_ref, *, x_out, y_out, stride):
    """One batch image x one K tile.

    i_ref: (1, XH, YH, C); w_ref: (FX, FY, C, Tk); o_ref: (1, X, Y, Tk).
    """
    fx, fy, c, tk = w_ref.shape
    inp = i_ref[0]  # (XH, YH, C)
    acc = jnp.zeros((x_out * y_out, tk), dtype=jnp.float32)
    # Filter taps are static python loops -> fully unrolled in the HLO,
    # matching the RF-resident FX/FY loops of the hardware schedule.
    for dx in range(fx):
        for dy in range(fy):
            patch = jax.lax.slice(
                inp,
                (dx, dy, 0),
                (dx + (x_out - 1) * stride + 1, dy + (y_out - 1) * stride + 1, c),
                (stride, stride, 1),
            )  # (X, Y, C)
            acc += jnp.dot(
                patch.reshape(x_out * y_out, c),
                w_ref[dx, dy],
                preferred_element_type=jnp.float32,
            )
    o_ref[0] = acc.reshape(x_out, y_out, tk).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("stride", "block_k", "interpret")
)
def conv2d_tiled(inp, w, *, stride=1, block_k=64, interpret=True):
    """Tiled CONV: ([B,XH,YH,C], [FX,FY,C,K]) -> [B,X,Y,K]."""
    b, xh, yh, c = inp.shape
    fx, fy, c2, k = w.shape
    assert c == c2, f"channel mismatch {c} vs {c2}"
    x_out = (xh - fx) // stride + 1
    y_out = (yh - fy) // stride + 1
    bk = pick_block(k, block_k)

    kernel = functools.partial(
        _conv_kernel, x_out=x_out, y_out=y_out, stride=stride
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, k // bk),
        in_specs=[
            pl.BlockSpec((1, xh, yh, c), lambda bi, ki: (bi, 0, 0, 0)),
            pl.BlockSpec((fx, fy, c, bk), lambda bi, ki: (0, 0, 0, ki)),
        ],
        out_specs=pl.BlockSpec((1, x_out, y_out, bk), lambda bi, ki: (bi, 0, 0, ki)),
        out_shape=jax.ShapeDtypeStruct((b, x_out, y_out, k), jnp.float32),
        interpret=interpret,
    )(inp.astype(jnp.float32), w.astype(jnp.float32))
    return out.astype(inp.dtype)


def _dw_kernel(i_ref, w_ref, o_ref, *, x_out, y_out, stride):
    """Depthwise tap accumulation: one batch image x one C tile."""
    fx, fy, tc = w_ref.shape
    inp = i_ref[0]  # (XH, YH, Tc)
    acc = jnp.zeros((x_out, y_out, tc), dtype=jnp.float32)
    for dx in range(fx):
        for dy in range(fy):
            patch = jax.lax.slice(
                inp,
                (dx, dy, 0),
                (dx + (x_out - 1) * stride + 1, dy + (y_out - 1) * stride + 1, tc),
                (stride, stride, 1),
            )
            acc += patch * w_ref[dx, dy]
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("stride", "block_c", "interpret")
)
def depthwise_conv2d_tiled(inp, w, *, stride=1, block_c=128, interpret=True):
    """Depthwise CONV: ([B,XH,YH,C], [FX,FY,C]) -> [B,X,Y,C].

    MobileNet's depthwise layer is the 7-loop nest with the C loop fused to
    K (one filter per channel) — the VPU (elementwise) path on TPU, not the
    MXU, so the kernel accumulates tap-shifted elementwise products.
    """
    b, xh, yh, c = inp.shape
    fx, fy, c2 = w.shape
    assert c == c2
    x_out = (xh - fx) // stride + 1
    y_out = (yh - fy) // stride + 1
    bc = pick_block(c, block_c)

    kernel = functools.partial(_dw_kernel, x_out=x_out, y_out=y_out, stride=stride)
    out = pl.pallas_call(
        kernel,
        grid=(b, c // bc),
        in_specs=[
            pl.BlockSpec((1, xh, yh, bc), lambda bi, ci: (bi, 0, 0, ci)),
            pl.BlockSpec((fx, fy, bc), lambda bi, ci: (0, 0, ci)),
        ],
        out_specs=pl.BlockSpec((1, x_out, y_out, bc), lambda bi, ci: (bi, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((b, x_out, y_out, c), jnp.float32),
        interpret=interpret,
    )(inp.astype(jnp.float32), w.astype(jnp.float32))
    return out.astype(inp.dtype)


def conv_vmem_words(b, xh, yh, c, fx, fy, k, block_k):
    """VMEM working-set (words) for one conv grid step."""
    bk = pick_block(k, block_k)
    x_out = xh - fx + 1
    y_out = yh - fy + 1
    return xh * yh * c + fx * fy * c * bk + x_out * y_out * bk
