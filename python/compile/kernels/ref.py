"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle in f32 (and to bf16 tolerance in bf16). The oracles
use only stock jax.numpy / lax ops so they lower to plain HLO everywhere.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(inp, w, stride=1):
    """Reference CONV layer.

    Args:
      inp: [B, XH, YH, C] input fmaps, already padded (XH = (X-1)*stride+FX).
      w:   [FX, FY, C, K] filter weights.
      stride: spatial stride.

    Returns:
      [B, X, Y, K] output fmaps.
    """
    dn = lax.conv_dimension_numbers(inp.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    return lax.conv_general_dilated(
        inp,
        w,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=dn,
        preferred_element_type=jnp.float32,
    ).astype(inp.dtype)


def matmul_ref(a, b):
    """Reference FC / matmul: [M, C] @ [C, N] -> [M, N]."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def lstm_cell_ref(x, h, c, w_ih, w_hh, bias):
    """Reference LSTM cell (seq2seq-style).

    Args:
      x: [B, E] input embedding.
      h: [B, H] previous hidden state.
      c: [B, H] previous cell state.
      w_ih: [E, 4H] input->gates weights, gate order (i, f, g, o).
      w_hh: [H, 4H] hidden->gates weights.
      bias: [4H].

    Returns:
      (h_next [B, H], c_next [B, H])
    """
    gates = (
        matmul_ref(x, w_ih).astype(jnp.float32)
        + matmul_ref(h, w_hh).astype(jnp.float32)
        + bias.astype(jnp.float32)
    )
    hdim = h.shape[-1]
    i = lax.logistic(gates[:, 0 * hdim : 1 * hdim])
    f = lax.logistic(gates[:, 1 * hdim : 2 * hdim])
    g = jnp.tanh(gates[:, 2 * hdim : 3 * hdim])
    o = lax.logistic(gates[:, 3 * hdim : 4 * hdim])
    c_next = f * c.astype(jnp.float32) + i * g
    h_next = o * jnp.tanh(c_next)
    return h_next.astype(h.dtype), c_next.astype(c.dtype)


def depthwise_conv2d_ref(inp, w, stride=1):
    """Reference depthwise CONV (MobileNet): one filter per channel.

    Args:
      inp: [B, XH, YH, C] padded input.
      w:   [FX, FY, C] per-channel filters.

    Returns:
      [B, X, Y, C].
    """
    c = inp.shape[-1]
    rhs = w[:, :, None, :]  # (FX, FY, 1, C): 1 input feature per group
    dn = lax.conv_dimension_numbers(inp.shape, rhs.shape, ("NHWC", "HWIO", "NHWC"))
    return lax.conv_general_dilated(
        inp,
        rhs,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=dn,
        feature_group_count=c,
        preferred_element_type=jnp.float32,
    ).astype(inp.dtype)
