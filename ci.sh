#!/usr/bin/env bash
# CI entry point: format, lint, build, and the tier-1 verify.
# Usage: ./ci.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> tier-1 verify: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "==> perf_search (pruning contract: identical winners, >=3x fewer full evals)"
    cargo bench --bench perf_search

    echo "==> perf_netopt (network B&B: identical winner, strictly fewer arch points; emits BENCH_netopt.json)"
    cargo bench --bench perf_netopt
fi

echo "CI OK"
