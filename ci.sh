#!/usr/bin/env bash
# CI entry point. Three tiers (documented in ARCHITECTURE.md):
#
#   ./ci.sh --quick      fmt + clippy + `cargo test -q` (fast inner loop)
#   ./ci.sh --no-bench   quick + release build (the tier-1 verify; PR gate)
#   ./ci.sh              full: tier-1 + perf gates + BENCH_*.json /
#                        bench_history.jsonl schema check + bench-report
#                        regression gate + one-command artifact
#                        regeneration smoke (main-branch gate; appends to
#                        the perf trajectory — see BENCHMARKS.md)
set -euo pipefail
cd "$(dirname "$0")"

MODE=full
case "${1:-}" in
    --quick) MODE=quick ;;
    --no-bench) MODE=tier1 ;;
    "") ;;
    *) echo "usage: ./ci.sh [--quick|--no-bench]" >&2; exit 2 ;;
esac

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -- -D warnings"
cargo clippy --all-targets -- -D warnings

if [[ "$MODE" == "quick" ]]; then
    # `cargo test -q` is the whole tier-1 test set, including the serve
    # determinism, remap equivalence, and seeded-vs-cold suites
    # (coordinator::tests, netopt::tests) and the in-process fleet
    # scenario smoke (fleet::tests — the thread-mode variant of the
    # perf_fleet gate below) — all artifact-free.
    echo "==> cargo test -q"
    cargo test -q
    echo "CI OK (quick)"
    exit 0
fi

echo "==> tier-1 verify: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

if [[ "$MODE" == "tier1" ]]; then
    echo "CI OK (tier-1, benches skipped)"
    exit 0
fi

# Stamp every perf-trajectory record from this run with one (rev, ts)
# pair so bench-report can group and label them consistently.
INTERSTELLAR_BENCH_GIT_REV="$(git rev-parse --short HEAD 2> /dev/null || echo unknown)"
INTERSTELLAR_BENCH_UNIX_TS="$(date +%s)"
export INTERSTELLAR_BENCH_GIT_REV INTERSTELLAR_BENCH_UNIX_TS

echo "==> perf_search (pruning contract: identical winners, >=3x fewer full evals; emits BENCH_search.json)"
cargo bench --bench perf_search

echo "==> perf_netopt (network B&B: identical winner, strictly fewer arch points; emits BENCH_netopt.json)"
cargo bench --bench perf_netopt

echo "==> perf_shard (multi-process shard equivalence: N workers + merge == single process, bit for bit; emits BENCH_shard.json)"
cargo bench --bench perf_shard

echo "==> perf_remap (serving-time remapping: deterministic serving, warm-started online plan == offline optimizer, drift tracked, deadline fast path beats eager to first plan; emits BENCH_remap.json)"
cargo bench --bench perf_remap

echo "==> perf_fastmap (heuristic mapper: >=100x over full-effort b&b, <=5% energy gap, scout priming bit-identical with fewer full evals; emits BENCH_fastmap.json)"
cargo bench --bench perf_fastmap

echo "==> perf_pareto (frontier exactness: dominance-pruned frontier == exhaustive + filter bit for bit, strictly fewer full evals, budget selection == scalar min-tops winner; emits BENCH_pareto.json)"
cargo bench --bench perf_pareto

echo "==> perf_hotpath (L3 hot-path microbenchmarks; emits BENCH_hotpath.json)"
cargo bench --bench perf_hotpath

echo "==> perf_orchestrator (distributed fan-out: >=2.5x at 4 workers, streamed bounds strictly cut full evals, SIGKILL survived via stealing, merged winner/frontier bit-identical; emits BENCH_orchestrator.json)"
cargo bench --bench perf_orchestrator

echo "==> perf_fleet (serving fleet: 4-worker merged digest bit-identical to single-process serve, SIGKILL crash + rejoin on the broadcast epoch, full scenario catalogue as OS processes, p50/p99/p99.9 under load; emits BENCH_fleet.json)"
cargo bench --bench perf_fleet

echo "==> perf_telemetry (tracing-off runs bit-identical, tracing-on same bits within 5% overhead, traced co-opt + fleet trace schema-valid with zero orphaned spans; emits BENCH_telemetry.json)"
cargo bench --bench perf_telemetry

echo "==> bench_schema (every BENCH_*.json + bench_history.jsonl conform to the documented schemas; all ten perf files required)"
cargo bench --bench bench_schema

echo "==> bench-report --check (no metric regressed against its own history; see BENCHMARKS.md)"
target/release/interstellar bench-report --check

echo "==> bench-report --check self-test (synthetic regression must fail the gate)"
SYN="$(mktemp)"
for ns in 101 104 102 105 103 250; do
    printf '\n{"v":1,"bench":"perf_probe","git_rev":"syn","unix_ts":%s,"metrics":{"probe_mean_ns":%s},"labels":{}}\n' "$ns" "$ns" >> "$SYN"
done
if target/release/interstellar bench-report --check --history "$SYN" > /dev/null 2>&1; then
    echo "FAIL: bench-report --check passed on a synthetically injected regression" >&2
    rm -f "$SYN"
    exit 1
fi
rm -f "$SYN"
echo "synthetic regression correctly rejected"

# Same self-test for a serving-latency spike: a stable p99 series ending
# in a 2.5x tail blowup must fail the gate (the `_ms` suffix opts
# latency percentiles into lower-is-better gating).
SYN="$(mktemp)"
i=0
for ms in 10.1 10.4 10.2 10.5 10.3 25.0; do
    i=$((i + 1))
    printf '\n{"v":1,"bench":"perf_probe_fleet","git_rev":"syn","unix_ts":%s,"metrics":{"probe_p99_ms":%s},"labels":{}}\n' "$i" "$ms" >> "$SYN"
done
if target/release/interstellar bench-report --check --history "$SYN" > /dev/null 2>&1; then
    echo "FAIL: bench-report --check passed on a synthetic p99 latency spike" >&2
    rm -f "$SYN"
    exit 1
fi
rm -f "$SYN"
echo "synthetic p99 latency spike correctly rejected"

# Telemetry end-to-end: one traced orchestrated sweep (the parent and
# its worker processes inherit INTERSTELLAR_TRACE and append to one
# shared trace) plus one traced fleet run, then the trace-report gate:
# schema-valid records, zero orphaned spans, all four instrumented
# planes present. See OBSERVABILITY.md.
TRACE_DIR="$(mktemp -d)"
TRACE_FILE="$TRACE_DIR/trace.jsonl"
echo "==> traced orchestrate (engine/search records from workers, orchestrator spans from the parent)"
INTERSTELLAR_TRACE="$TRACE_FILE" target/release/interstellar orchestrate \
    --mode co-opt --net alexnet --batch 1 --head 2 --space full --rows 8 --cols 8 \
    --rf1 16,64 --rf2-ratio 8 --gbuf 65536,262144 --ratio-min 0.25 --ratio-max 64 \
    --cap 150 --divisors 4 --orders 9 --workers 2 --nshards 4 --worker-threads 1 \
    --dir "$TRACE_DIR/orch" > /dev/null

echo "==> traced fleet (per-batch spans, latency histograms, plan events into the same trace)"
INTERSTELLAR_TRACE="$TRACE_FILE" target/release/interstellar fleet \
    --workers 2 --requests 96 --window 24 --drift 0.25 --in-process \
    --dir "$TRACE_DIR/fleet" > /dev/null

echo "==> trace-report --check (schema-valid, zero orphaned spans, engine+search+orchestrator+fleet planes)"
target/release/interstellar trace-report --trace "$TRACE_FILE" --check \
    --require-planes engine,search,orchestrator,fleet

echo "==> trace-report (the rendered profile tree / utilization / latency view)"
target/release/interstellar trace-report --trace "$TRACE_FILE"
rm -rf "$TRACE_DIR"

echo "==> report --all --smoke (one-command paper-artifact regeneration; see REPRODUCING.md)"
target/release/interstellar report --all --smoke --out report-artifacts

echo "CI OK"
